#!/usr/bin/env bash
# Fast local gate: tier-1 tests + benchmark smoke.
#
#   scripts/check.sh          # fast: skip slow-marked multidevice/driver tests
#   scripts/check.sh --full   # full tier-1 suite (what the CI/driver runs)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

python -m benchmarks.run --smoke
