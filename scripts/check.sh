#!/usr/bin/env bash
# Fast local gate: tier-1 tests + benchmark smoke.
#
#   scripts/check.sh          # fast: skip slow-marked multidevice/driver tests
#   scripts/check.sh --full   # full tier-1 suite (what the CI/driver runs)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

# Benchmark smoke; --json leaves a machine-readable JoinStats trail and
# --trajectory appends this run's summary to the repo-root perf history
# (newest BENCH_PR*.json by default — no manual bump per PR; override via
# REPRO_BENCH_TRAJECTORY) so filter-ratio / perf trajectories accumulate
# across PRs.
python -m benchmarks.run --smoke \
    --json "${REPRO_BENCH_JSON:-/tmp/repro_bench_smoke.json}" \
    --trajectory="${REPRO_BENCH_TRAJECTORY:-}"

# Perf-regression gate: compare this run's gated kernel rows (pair_verdict,
# entry_filter, indexed chunk step, hamming) against the previous trajectory
# entries and fail on >1.3x us_per_call regressions; prints the one-line
# roofline summary (achieved-vs-peak bytes/flops, bottleneck) per row.
# Skips with a warning when no prior entry has matching rows.  Waive an
# intentional regression with REPRO_PERF_GATE_WAIVE=1.
python -m benchmarks.perf_gate --trajectory="${REPRO_BENCH_TRAJECTORY:-}"

# Compaction-path smoke: the device-resident join must reproduce the host
# path's pairs exactly on a real R×S workload.
python -m benchmarks.bench_rs_join --resident

# Engine smoke: prepare a corpus once, probe it twice; the second probe must
# reuse the cached length sort + bitmap words (asserted via build counters)
# and return oracle-identical pairs.
python -m benchmarks.bench_engine --smoke

# Indexed-driver smoke: same contract through an "indexed" plan — the second
# probe must reuse the cached postings-CSR index (builds["postings"] == 1)
# and both probes must match the oracle exactly.
python -m benchmarks.bench_engine --indexed-smoke

# Sharded-indexed smoke: the mesh twin — prepare once, probe twice through a
# "sharded-indexed" plan; the token-slab partition must be built exactly once
# (builds["sharded_postings"] == 1) and both probes must match the oracle.
python -m benchmarks.bench_engine --sharded-smoke

# Serving smoke: a resident JoinSession coalesces a saturated request
# stream into >=3 padded batches, the bucketed entrypoint cache shows zero
# retraces after warmup (trace counters), every per-request pair list and
# JoinStats is bit-identical to sequential JoinEngine.probe, and sustained
# throughput is >=2x the per-request path.
python -m benchmarks.bench_serve --smoke

# Store smoke: N appends onto a CorpusStore never rebuild the sealed base
# (asserted via build counters), and after a compaction the store's pairs
# and summed funnel stats are bit-identical to a from-scratch rebuild.
python -m benchmarks.bench_store --smoke

# Mesh conformance gate: re-run the single driver-conformance suite on an
# 8-virtual-device harness, so multi-device regressions (ring and
# sharded-indexed alike) are caught without hardware.  The sharded-indexed
# executor pins its pairs AND summed JoinStats to the single-device indexed
# driver on every grid cell.
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -x -q tests/test_driver_conformance.py
