#!/usr/bin/env bash
# Fast local gate: tier-1 tests + benchmark smoke.
#
#   scripts/check.sh          # fast: skip slow-marked multidevice/driver tests
#   scripts/check.sh --full   # full tier-1 suite (what the CI/driver runs)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

# Benchmark smoke; --json leaves a machine-readable JoinStats trail and
# --trajectory appends this run's summary to the repo-root perf history
# (BENCH_PR4.json by default, parameterized via REPRO_BENCH_TRAJECTORY) so
# filter-ratio / perf trajectories accumulate across PRs.
python -m benchmarks.run --smoke \
    --json "${REPRO_BENCH_JSON:-/tmp/repro_bench_smoke.json}" \
    --trajectory "${REPRO_BENCH_TRAJECTORY:-BENCH_PR4.json}"

# Compaction-path smoke: the device-resident join must reproduce the host
# path's pairs exactly on a real R×S workload.
python -m benchmarks.bench_rs_join --resident

# Engine smoke: prepare a corpus once, probe it twice; the second probe must
# reuse the cached length sort + bitmap words (asserted via build counters)
# and return oracle-identical pairs.
python -m benchmarks.bench_engine --smoke

# Indexed-driver smoke: same contract through an "indexed" plan — the second
# probe must reuse the cached postings-CSR index (builds["postings"] == 1)
# and both probes must match the oracle exactly.
python -m benchmarks.bench_engine --indexed-smoke
