"""Paper Table 9 — Bitmap Filter ratio (pruned / candidates) per collection
and threshold, measured inside AllPairs (as the paper does)."""

from __future__ import annotations

import time
from typing import List

from benchmarks.common import Row, collection
from repro.core import cpu_algos
from repro.core.filters import BitmapFilter

TAUS = (0.5, 0.7, 0.8, 0.9)
PAPER_UNIFORM = {0.5: 0.99, 0.7: 0.99, 0.8: 0.99, 0.9: 0.99}


def run() -> List[Row]:
    rows: List[Row] = []
    for cname, n in (("uniform", 2000), ("zipf", 1200), ("dblp", 700)):
        col = collection(cname, n)
        b = 128 if cname in ("zipf", "dblp") else 64
        for tau in TAUS:
            bf = BitmapFilter.build(col.tokens, col.lengths, "jaccard", tau, b=b)
            stats = cpu_algos.AlgoStats()
            t0 = time.perf_counter()
            cpu_algos.allpairs(col, "jaccard", tau, bitmap=bf, stats=stats)
            dt = (time.perf_counter() - t0) * 1e6
            ratio = stats.bitmap_pruned / max(stats.candidates, 1)
            rows.append(Row(
                f"table9_ratio_{cname}_tau{tau}", dt,
                f"filter_ratio={ratio:.3f} candidates={stats.candidates} "
                f"pruned={stats.bitmap_pruned} verified={stats.verified}"))
    return rows
