"""Paper Fig. 11 — filtering precision vs set size (cutoff disabled).

Precision = true positives / unfiltered candidates, bucketed by |r|;
the drop-off past the analytic cutoff point is the effect the paper's
cutoff rule exploits."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, collection
from repro.core import bounds, expected, verify
from repro.core import bitmap as bm
from repro.core.filters import BitmapFilter
import jax.numpy as jnp


def run() -> List[Row]:
    rows: List[Row] = []
    col = collection("zipf", 1200)
    tau = 0.6
    b = 64
    t0 = time.perf_counter()
    bf = BitmapFilter.build(col.tokens, col.lengths, "jaccard", tau, b=b,
                            use_cutoff=False)
    lens = np.asarray(col.lengths)
    toks = jnp.asarray(col.tokens)
    buckets = [(1, 20), (20, 40), (40, 80), (80, 1000)]
    stats = {bk: [0, 0] for bk in buckets}  # unfiltered, true
    for i in range(col.num_sets):
        js = np.arange(i + 1, col.num_sets)
        if len(js) == 0:
            continue
        lo, hi = bounds.length_bounds("jaccard", tau, int(lens[i]))
        js = js[(lens[js] >= lo) & (lens[js] <= hi)]
        if len(js) == 0:
            continue
        pruned = bf.prune_mask(i, js)
        surv = js[~pruned]
        if len(surv) == 0:
            continue
        ok = np.asarray(verify.verify_pairs(
            toks, jnp.asarray(col.lengths), jnp.full(len(surv), i), jnp.asarray(surv),
            "jaccard", tau))
        for bk in buckets:
            if bk[0] <= lens[i] < bk[1]:
                stats[bk][0] += len(surv)
                stats[bk][1] += int(ok.sum())
    dt = (time.perf_counter() - t0) * 1e6
    cut = expected.cutoff_point(bf.method, b, tau)
    parts = []
    for bk in buckets:
        unf, true = stats[bk]
        prec = true / unf if unf else float("nan")
        parts.append(f"|r|in[{bk[0]},{bk[1]}):{prec:.3f}(n={unf})")
    rows.append(Row("fig11_precision_vs_size", dt,
                    " ".join(parts) + f" analytic_cutoff={cut}"))
    return rows
