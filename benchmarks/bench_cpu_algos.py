"""Paper Tables 5-8 — the four CPU algorithms with and without the Bitmap
Filter.

Reports per (collection x threshold x algorithm): original runtime, +BF
runtime, and the paper's improvement metric (t_orig/t_bf - 1).  Aggregates
reproduce the headline claims: ~90% of inputs improved, 43% average
improvement, worst slowdown bounded (~-9%)."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, collection
from repro.core import cpu_algos
from repro.core.filters import BitmapFilter

ALGOS = ("allpairs", "ppjoin", "groupjoin", "adaptjoin")
TAUS = (0.5, 0.7, 0.8, 0.9)
COLS = {"uniform": 2000, "zipf": 1200, "dblp": 700}


def _b_for(col_name: str) -> int:
    # Paper §5.1: b=64 default; 128 for large-median collections (DBLP/ZIPF).
    return 128 if col_name in ("zipf", "dblp") else 64


def run() -> List[Row]:
    rows: List[Row] = []
    improvements = []
    improved = 0
    total = 0
    for cname, n in COLS.items():
        col = collection(cname, n)
        for tau in TAUS:
            bf = BitmapFilter.build(col.tokens, col.lengths, "jaccard", tau,
                                    b=_b_for(cname))
            for algo in ALGOS:
                fn = cpu_algos.ALGORITHMS[algo]
                t0 = time.perf_counter()
                base = fn(col, "jaccard", tau)
                t_orig = time.perf_counter() - t0
                t0 = time.perf_counter()
                with_bf = fn(col, "jaccard", tau, bitmap=bf)
                t_bf = time.perf_counter() - t0
                assert np.array_equal(base, with_bf)
                imp = (t_orig / t_bf - 1.0) * 100.0
                improvements.append(imp)
                improved += imp > 0
                total += 1
                rows.append(Row(
                    f"table5_{cname}_tau{tau}_{algo}", t_bf * 1e6,
                    f"orig_us={t_orig*1e6:.0f} bf_us={t_bf*1e6:.0f} "
                    f"improvement={imp:+.1f}% pairs={len(base)}"))
    rows.append(Row(
        "table6_aggregate", 0.0,
        f"avg_improvement={np.mean(improvements):.1f}% (paper 43%) "
        f"improved={100*improved/total:.0f}% of inputs (paper 90%) "
        f"worst={np.min(improvements):.1f}% (paper >=-9%) "
        f"best={np.max(improvements):.1f}% (paper up to 350%)"))
    return rows
