"""Perf-regression gate over the BENCH_* trajectory.

The trajectory JSON (appended by ``benchmarks.run --trajectory``) used to
record history but protect nothing.  This module makes it a gate: the newest
entry's gated kernel rows are compared against prior entries, and any row
whose ``us_per_call`` regressed by more than the threshold ratio fails the
run — ``scripts/check.sh`` invokes it right after the benchmark smoke, so a
slowed hot-path kernel turns the whole check red.

Rules, designed for noisy wall-clock timings on a shared CPU container:

* only rows whose names start with one of ``GATED_PREFIXES`` are gated (the
  three hot paths of the indexed funnel plus the dense hamming kernel);
* the baseline is the *minimum* ``us_per_call`` over the last ``LOOKBACK``
  prior entries that contain the same row name and the same ``smoke`` flag
  (smoke and full runs use different shapes — row names embed the shape, so
  they can never alias, and the flag keeps entry row-sets comparable);
* a row with no prior baseline is reported as ``new`` and skipped with a
  warning, never failed — the first run after adding a kernel (or starting a
  fresh ``BENCH_PR*.json``) establishes the baseline;
* baselines faster than ``MIN_PRIOR_US`` are timer noise and skipped;
* ``REPRO_PERF_GATE_RATIO`` overrides the 1.3x threshold, and setting
  ``REPRO_PERF_GATE_WAIVE=1`` downgrades failures to warnings (the escape
  hatch for intentional trade-offs — record why in the PR).

Beyond per-call microseconds, ``GATED_FIELDS`` gates named *fields* of
matching rows — the serving rows carry ``stats.probes_per_sec``
(higher-is-better: the comparison inverts, a drop below ``baseline/ratio``
fails) and a top-level ``p99_us`` tail latency (lower-is-better, gated like
``us_per_call`` with the same noise floor but a wider per-field margin —
tail percentiles jitter more than means).  The baseline is the *best*
prior value (max for throughput, min for latency) over the lookback window,
so run-to-run jitter never ratchets the bar down.

Also prints a one-line-per-row roofline summary (achieved-vs-peak bytes,
bottleneck term, measured-vs-bound gap) from the roofline stats that
``bench_kernels`` attaches to each row.
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys
from typing import List, Optional

from benchmarks.run import default_trajectory

DEFAULT_RATIO = 1.3
LOOKBACK = 3
MIN_PRIOR_US = 50.0
GATED_PREFIXES = (
    "kernel_pair_verdict",
    "kernel_entry_filter",
    "kernel_indexed_chunk",
    "kernel_hamming",
    "store_append",
    "store_probe",
)
# (row-name prefix, field path, direction, margin).  "higher" inverts the
# comparison: the metric regressing means it *dropped* (throughput);
# "lower" gates like us_per_call (latency).  Dotted paths descend into the
# row's ``stats`` dict.  ``margin`` multiplies the gate ratio for that
# field: tail percentiles (p99 over a few hundred requests) jitter far more
# run-to-run on a time-shared CPU than means do, and the baseline is the
# best-ever prior — so the p99 gate only fires on a ~2x structural
# regression, not scheduler noise.
GATED_FIELDS = (
    ("serve_sustained", "stats.probes_per_sec", "higher", 1.0),
    ("serve_sustained", "p99_us", "lower", 1.5),
)
RATIO_ENV = "REPRO_PERF_GATE_RATIO"
WAIVE_ENV = "REPRO_PERF_GATE_WAIVE"


@dataclasses.dataclass
class Verdict:
    name: str
    us: float                      # the gated value (unit may differ)
    baseline_us: Optional[float]   # None -> no prior entry had this row
    ratio: Optional[float]         # regression factor: >1 means worse
    status: str                    # "ok" | "fail" | "new" | "noise"
    roofline: Optional[dict] = None
    unit: str = "us"

    def line(self) -> str:
        base = ("baseline=none" if self.baseline_us is None
                else f"baseline={self.baseline_us:.1f}{self.unit} "
                     f"ratio={self.ratio:.2f}")
        roof = ""
        if self.roofline:
            r = self.roofline
            roof = (f" | roofline: bytes={r['hbm_bytes']:.3g} "
                    f"flops={r['flops']:.3g} "
                    f"ach_bytes={r['achieved_bytes_s']:.3g}B/s "
                    f"bottleneck={r['bottleneck']} gap={r['gap']:.3g}")
        return (f"{self.status.upper():5s} {self.name}: "
                f"{self.us:.1f}{self.unit} {base}{roof}")


def load_trajectory(path: str) -> list:
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            loaded = json.load(f)
    except (json.JSONDecodeError, OSError):
        return []
    return loaded if isinstance(loaded, list) else []


def _gated_rows(entry: dict) -> dict:
    out = {}
    for row in entry.get("rows", []):
        name = row.get("name", "")
        if any(name.startswith(p) for p in GATED_PREFIXES):
            out[name] = row
    return out


def _field_value(row: dict, path: str) -> Optional[float]:
    cur: object = row
    for part in path.split("."):
        if not isinstance(cur, dict):
            return None
        cur = cur.get(part)
    return float(cur) if isinstance(cur, (int, float)) else None


def _field_unit(path: str) -> str:
    if path.endswith("_us"):
        return "us"
    if path.endswith("_per_sec"):
        return "/s"
    return ""


def check_trajectory(history: list, ratio: float = DEFAULT_RATIO) -> List[Verdict]:
    """Gate the newest entry against prior same-smoke entries.

    Returns one :class:`Verdict` per gated row of the newest entry (plus one
    per gated *field*, named ``row[field]``); an empty list means the
    trajectory has no entries (or none with gated rows).
    """
    if not history:
        return []
    current = history[-1]
    priors = [e for e in history[:-1]
              if e.get("smoke") == current.get("smoke")][-LOOKBACK:]
    verdicts = []
    for name, row in sorted(_gated_rows(current).items()):
        us = float(row["us_per_call"])
        roof = (row.get("stats") or {}).get("roofline")
        prior_us = [float(r["us_per_call"])
                    for e in priors for r in e.get("rows", [])
                    if r.get("name") == name]
        if not prior_us:
            verdicts.append(Verdict(name, us, None, None, "new", roof))
            continue
        base = min(prior_us)
        r = us / base if base > 0 else float("inf")
        if base < MIN_PRIOR_US:
            verdicts.append(Verdict(name, us, base, r, "noise", roof))
        elif r > ratio:
            verdicts.append(Verdict(name, us, base, r, "fail", roof))
        else:
            verdicts.append(Verdict(name, us, base, r, "ok", roof))
    verdicts.extend(_check_fields(current, priors, ratio))
    return verdicts


def _check_fields(current: dict, priors: list,
                  ratio: float) -> List[Verdict]:
    """Gate ``GATED_FIELDS`` metrics of the newest entry's matching rows."""
    verdicts = []
    for row in current.get("rows", []):
        name = row.get("name", "")
        for prefix, path, direction, margin in GATED_FIELDS:
            if not name.startswith(prefix):
                continue
            value = _field_value(row, path)
            if value is None:
                continue
            vname = f"{name}[{path}]"
            unit = _field_unit(path)
            prior = [v for e in priors for r in e.get("rows", [])
                     if r.get("name") == name
                     for v in [_field_value(r, path)] if v is not None]
            if not prior:
                verdicts.append(Verdict(vname, value, None, None, "new",
                                        unit=unit))
                continue
            if direction == "higher":
                # Throughput: baseline is the best (max) prior; the
                # regression factor is how far we fell below it.
                base = max(prior)
                r = base / value if value > 0 else float("inf")
                noise = base <= 0
            else:
                base = min(prior)
                r = value / base if base > 0 else float("inf")
                noise = unit == "us" and base < MIN_PRIOR_US
            status = ("noise" if noise else
                      "fail" if r > ratio * margin else "ok")
            verdicts.append(Verdict(vname, value, base, r, status,
                                    unit=unit))
    return verdicts


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    path = None
    for a in argv:
        if a == "--trajectory":
            path = default_trajectory()
        elif a.startswith("--trajectory="):
            path = a.split("=", 1)[1] or default_trajectory()
        else:
            raise SystemExit(f"unknown argument {a!r}")
    if path is None:
        path = default_trajectory()
    ratio = float(os.environ.get(RATIO_ENV, DEFAULT_RATIO))
    waive = bool(os.environ.get(WAIVE_ENV))

    history = load_trajectory(path)
    if not history:
        print(f"perf-gate: SKIP — no trajectory entries at {path}")
        return 0
    verdicts = check_trajectory(history, ratio)
    if not verdicts:
        print(f"perf-gate: SKIP — newest entry in {path} has no gated rows "
              f"(prefixes: {', '.join(GATED_PREFIXES)})")
        return 0

    print(f"perf-gate: {path} entry {len(history)} "
          f"(smoke={history[-1].get('smoke')}), threshold {ratio:.2f}x, "
          f"baseline = min of last {LOOKBACK} matching entries")
    for v in verdicts:
        print("  " + v.line())
    failures = [v for v in verdicts if v.status == "fail"]
    fresh = [v for v in verdicts if v.status == "new"]
    if fresh and len(fresh) == len(verdicts):
        print("perf-gate: SKIP — no prior trajectory entry with matching "
              "row names (baseline established by this run)")
        return 0
    if failures:
        names = ", ".join(v.name for v in failures)
        if waive:
            print(f"perf-gate: WAIVED {len(failures)} regression(s) "
                  f"({names}) — {WAIVE_ENV} is set")
            return 0
        print(f"perf-gate: FAIL — {len(failures)} gated row(s) regressed "
              f">{ratio:.2f}x vs baseline: {names}")
        print(f"perf-gate: waive intentionally with {WAIVE_ENV}=1, or adjust "
              f"{RATIO_ENV}")
        return 1
    print("perf-gate: PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
