"""Paper Fig. 5 / Eq. 4-6 — expected overlap upper bounds vs Monte-Carlo.

Validates the paper's claim that the closed forms match simulation (they
report 0.012% average error over n in [1,128], b=64)."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core import expected
from repro.core.constants import BITMAP_METHODS


def run() -> List[Row]:
    rows: List[Row] = []
    b = 64
    ns = (4, 16, 32, 55, 64, 96, 128)
    for method in BITMAP_METHODS:
        errs = []
        t0 = time.perf_counter()
        for n in ns:
            ana = float(expected.expected_bound(method, b, n))
            mc = expected.monte_carlo_expected_bound(method, b, n, trials=20000)
            errs.append(abs(ana - mc) / n)   # paper normalises on the n scale
        dt = (time.perf_counter() - t0) * 1e6 / len(ns)
        rows.append(Row(
            f"fig5_expected_bound_{method}", dt,
            f"avg_err/n={np.mean(errs):.5f} max={np.max(errs):.5f} "
            f"(paper: ~0.00012; Eq.6/Next is itself approximate)"))
    # the paper's worked example: E/n at b=64, n=55 ~ 0.72 (jaccard ~0.84)
    e = float(expected.expected_bound("set", 64, 55))
    norm = e / 55
    jac = float(expected.jaccard_of_overlap(e, 55))
    inv = 2 * norm / (1 + norm)
    rows.append(Row(
        "fig5_worked_example_n55", 0.0,
        f"norm_bound={norm:.3f} (paper 0.72); equivalent-jaccard x/(2-x)={jac:.3f}; "
        f"paper's quoted 0.84 matches the inverse map 2x/(1+x)={inv:.3f} — "
        f"see expected.py docstring (scale swap in the paper's prose)"))
    return rows
