"""Online join serving: coalesced resident sessions vs per-request probes.

The ROADMAP north star is a service: R is a corpus that holds still, probe
requests arrive online — often as single sets.  ``JoinEngine.probe`` already
amortizes the corpus build, but each call still pays per-request dispatch
(host prepass, jit-call overhead, a blocking device round-trip).  The
serving layer (``repro.serve.JoinSession``) coalesces queued requests into
padded power-of-two batches, reuses bucketed traced entrypoints, and
double-buffers uploads — this benchmark measures what that buys on a
synthetic online workload:

* ``serve_sustained_*`` — saturated submission (requests always queued, the
  open-loop limit of an overloaded service): sustained probes/sec through
  the coalesced session, p50/p99 per-request latency, and the speedup over
  probing the same request stream one-at-a-time through ``JoinEngine``
  (both paths steady-state: measured on their second pass, jit caches
  warm).  The row carries ``stats.probes_per_sec`` and top-level
  ``p99_us`` — both gated by ``benchmarks/perf_gate.py`` (throughput with
  the comparison inverted).
* ``serve_open_loop_*`` (full runs only) — Poisson arrivals at half the
  measured sustained rate, the classic open-loop latency probe: requests
  arrive on a wall clock regardless of completions, ``poll`` flushes under
  the coalescer's max-wait policy, and p50/p99 include real queueing delay
  (batches are smaller than at saturation, so the offered rate is kept
  conservative — an overloaded open-loop run measures queue growth, not
  service latency).

``python -m benchmarks.bench_serve --smoke`` is the CI-gate flavour
(``scripts/check.sh``): it *asserts* the serving contract — resident
corpus built exactly once (build counters), ≥3 coalesced batches, zero
entrypoint retraces after the warmup pass (trace counters), every
per-request pair list and ``JoinStats`` bit-identical to sequential
``JoinEngine.probe``, and sustained throughput ≥2x the per-request path.
"""

from __future__ import annotations

import os
import time
from typing import List, Optional, Tuple

import numpy as np

from benchmarks.common import Row
from repro.core.collection import Collection, from_lists
from repro.core.engine import JoinEngine, prepare
from repro.serve import JoinSession

SIM = "jaccard"
TAU = 0.8
# Request set sizes come from a small palette so the *sequential* baseline's
# per-shape jit compiles stay bounded — the serving path wouldn't care (its
# buckets absorb shape variety), and a fixed palette keeps the comparison
# about steady-state dispatch, not compile amortization.
SIZES = (8, 12, 16)
PAD_TO = 16


def _workload(n_corpus: int, n_requests: int, seed: int = 0
              ) -> Tuple[Collection, List[Collection]]:
    """One corpus + single-set probe requests in a shared token universe,
    with planted exact corpus rows so probes return pairs."""
    rng = np.random.default_rng(seed)

    def draw_set() -> list:
        sz = int(rng.choice(SIZES))
        return np.unique(rng.integers(0, 900, size=2 * sz + 8))[:sz].tolist()

    corpus_sets = [draw_set() for _ in range(n_corpus)]
    requests = []
    for i in range(n_requests):
        s = (list(corpus_sets[int(rng.integers(0, n_corpus))])
             if i % 4 == 0 else draw_set())
        requests.append(from_lists([s], pad_to=PAD_TO))
    return from_lists(corpus_sets), requests


def _run_serve(sess: JoinSession, requests: List[Collection],
               flush_every: int) -> Tuple[list, float]:
    """Saturated submission: enqueue everything as fast as possible,
    flushing every ``flush_every`` submissions (deterministic groups — the
    retrace assertions rely on replaying identical buckets)."""
    t0 = time.perf_counter()
    tickets = []
    for i, r in enumerate(requests):
        tickets.append(sess.submit(r))
        if (i + 1) % flush_every == 0:
            sess.flush()
    sess.flush()
    return tickets, time.perf_counter() - t0


def _run_sequential(engine: JoinEngine, requests: List[Collection]
                    ) -> Tuple[list, float, np.ndarray]:
    t0 = time.perf_counter()
    out, lats = [], np.empty(len(requests))
    for i, r in enumerate(requests):
        q0 = time.perf_counter()
        out.append(engine.probe(r))
        lats[i] = time.perf_counter() - q0
    return out, time.perf_counter() - t0, lats


def _run_open_loop(sess: JoinSession, requests: List[Collection],
                   rate_hz: float) -> Tuple[list, float]:
    """Open-loop replay: fixed-rate arrivals at ``rate_hz`` on the wall
    clock, independent of completions; ``poll`` flushes under the max-wait
    policy.  Deterministic (not Poisson) gaps keep the coalesced group sizes
    — and so the shape buckets — stable, so the measured pass exercises warm
    entrypoints rather than XLA's compile latency (which on this CPU backend
    is ~1000x a flush and would swamp any queueing signal)."""
    start = time.perf_counter()
    arrivals = start + np.arange(1, len(requests) + 1) / rate_hz
    tickets = []
    for r, at in zip(requests, arrivals):
        # Poll at least once per arrival: when the service falls behind the
        # arrival clock, full batches must still flush mid-stream.
        sess.poll()
        while time.perf_counter() < at:
            sess.poll()
        tickets.append(sess.submit(r))
    sess.flush()
    return tickets, time.perf_counter() - start


def _latency_percentiles_us(tickets) -> Tuple[float, float]:
    lats = np.array([t.latency_s for t in tickets], dtype=np.float64) * 1e6
    return float(np.percentile(lats, 50)), float(np.percentile(lats, 99))


def _shapes() -> Tuple[int, int, int]:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    if smoke:
        return 600, 192, 16
    return 2000, 600, 32


def run(check: bool = False) -> List[Row]:
    n_corpus, n_requests, flush_every = _shapes()
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    corpus, requests = _workload(n_corpus, n_requests)

    sess = JoinSession(corpus, SIM, TAU, max_batch=flush_every * 4,
                       max_wait=0.002)
    seq_engine = JoinEngine(prepare(corpus), SIM, TAU, plan=sess.plan)

    # Pre-warm: ``warm_buckets`` compiles the full row-bucket ladder at the
    # calibrated capacity (the serving API a real service would call before
    # admitting traffic); the replay pass then proves the stream fits the
    # warmed buckets and warms the sequential baseline's jit caches too.
    sess.warm_buckets(requests[:flush_every * 4])
    warm_tickets, _ = _run_serve(sess, requests, flush_every)
    _run_sequential(seq_engine, requests)
    traces_after_warmup = sess.entrypoints.stats()["traces"]

    tickets, serve_s = _run_serve(sess, requests, flush_every)
    seq_results, seq_s, seq_lats = _run_sequential(seq_engine, requests)

    n = len(requests)
    probes_per_sec = n / serve_s
    seq_probes_per_sec = n / seq_s
    speedup = probes_per_sec / seq_probes_per_sec
    p50, p99 = _latency_percentiles_us(tickets)
    sp50 = float(np.percentile(seq_lats * 1e6, 50))
    sp99 = float(np.percentile(seq_lats * 1e6, 99))
    ep = sess.entrypoints.stats()
    summary = sess.stats_summary()

    if check:
        builds = summary["builds"]
        assert builds["sort"] == 1 and builds["bitmap"] == 1, builds
        assert builds["postings"] == 1, builds
        assert sess.coalesced_batches >= 3, (
            f"expected >=3 coalesced batches, got {sess.coalesced_batches}")
        assert ep["traces"] == traces_after_warmup, (
            f"entrypoints retraced at steady state: {traces_after_warmup} "
            f"-> {ep['traces']}")
        assert ep["max_traces_per_key"] == 1, ep
        mismatches = 0
        for t, wt, (sp, ss) in zip(tickets, warm_tickets, seq_results):
            cp, cs = t.result()
            wp, _ = wt.result()
            if not (np.array_equal(cp, sp) and np.array_equal(cp, wp)
                    and cs == ss):
                mismatches += 1
        assert mismatches == 0, f"{mismatches}/{n} requests not bit-identical"
        assert speedup >= 2.0, (
            f"coalesced serving only {speedup:.2f}x sequential "
            f"(serve {probes_per_sec:.0f}/s vs {seq_probes_per_sec:.0f}/s)")

    shape = f"n{n_requests}xc{n_corpus}"
    rows = [
        Row(f"serve_sustained_{shape}", serve_s / n * 1e6,
            f"probes_per_sec={probes_per_sec:.0f} "
            f"speedup_vs_sequential={speedup:.2f} "
            f"batches={sess.coalesced_batches} traces={ep['traces']}",
            stats={"probes_per_sec": probes_per_sec,
                   "sequential_probes_per_sec": seq_probes_per_sec,
                   "speedup": speedup,
                   "coalesced_batches": sess.coalesced_batches,
                   "coalesced_requests": sess.coalesced_requests,
                   "sequential_requests": sess.sequential_requests,
                   "entrypoint_traces": ep["traces"],
                   "pad_overhead": summary["pad_overhead"]},
            p50_us=p50, p99_us=p99),
        Row(f"serve_sequential_{shape}", seq_s / n * 1e6,
            f"probes_per_sec={seq_probes_per_sec:.0f} baseline",
            p50_us=sp50, p99_us=sp99),
    ]

    if not smoke:
        # The open-loop probe reuses the (long-lived, fully warm) session —
        # a resident service doesn't restart between load patterns, and a
        # fresh session would spend the measured window compiling buckets.
        rate = 0.5 * probes_per_sec
        # A longer max-wait for the open-loop phase: at half the saturated
        # rate, a 2ms window collects ~4 rows — per-flush overhead would
        # dominate and the service would fall behind its own arrival clock.
        # 10ms windows collect batches the warm buckets already cover.
        sess.coalescer.max_wait = 0.010
        b0 = sess.coalesced_batches
        ol_tickets, ol_s = _run_open_loop(sess, requests, rate)
        op50, op99 = _latency_percentiles_us(ol_tickets)
        rows.append(Row(
            f"serve_open_loop_{shape}", ol_s / n * 1e6,
            f"rate=0.5x_sustained probes_per_sec={n / ol_s:.0f} "
            f"batches={sess.coalesced_batches - b0}",
            stats={"probes_per_sec": n / ol_s,
                   "offered_rate_per_sec": rate,
                   "coalesced_batches": sess.coalesced_batches - b0},
            p50_us=op50, p99_us=op99))
    return rows


def run_serve_smoke() -> List[Row]:
    """CI gate (``scripts/check.sh``): the serving contract, asserted."""
    os.environ["REPRO_BENCH_SMOKE"] = "1"
    rows = run(check=True)
    print("# serve smoke OK: resident build-once, >=3 coalesced batches, "
          "zero steady-state retraces, bit-identical to sequential, >=2x")
    return rows


if __name__ == "__main__":
    import sys

    fn = run_serve_smoke if "--smoke" in sys.argv[1:] else run
    print("name,us_per_call,derived")
    for r in fn():
        print(r.csv(), flush=True)
