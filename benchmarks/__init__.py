# One module per paper table/figure; see run.py for the driver.
