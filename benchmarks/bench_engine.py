"""Prepared-collection engine: amortized probe throughput vs rebuild-per-call.

The serving question (ROADMAP north star): R is a corpus that holds still,
S arrives in batches.  Today's one-shot ``blocked_bitmap_join(col_r, col_s)``
re-derives the R-side length sort and bitmap words on *every* call; the
engine (``repro.core.engine.JoinEngine``) prepares R once and streams batches
through it.  This benchmark measures both shapes on the same workload and
*asserts* — via the ``PreparedCollection`` build counters — that the second
and every subsequent probe skips the length sort and bitmap generation
entirely.

``python -m benchmarks.bench_engine --smoke`` runs the CI gate flavour
(``scripts/check.sh``): prepare once, probe twice, assert the second probe
reuses the cached bitmap words and returns oracle-identical pairs.
``--indexed-smoke`` is the indexed-driver twin: prepare once, probe twice
through an ``"indexed"`` plan, assert the postings-CSR cache was built
exactly once (build counters) and both probes match the oracle.
``--sharded-smoke`` is the mesh twin: the same contract through a
``"sharded-indexed"`` plan over all available devices, additionally
asserting the token-slab partition (``builds["sharded_postings"]``) was
built exactly once and reused by the second probe.

``run()`` additionally measures indexed-vs-blocked on one skewed self-join
and ring-vs-sharded-indexed on the same mesh workload (all rows carry their
``JoinStats``, so the trajectory JSON records the candidate funnel of each
driver side by side).
"""

from __future__ import annotations

import os
import time
from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core import JACCARD, JoinEngine, JoinPlanner, prepare
from repro.core.collection import from_lists
from repro.core.join import blocked_bitmap_join, naive_join
from repro.core.plan import JoinPlan

TAU = 0.8
B = 128


def _corpus_and_batches(n_corpus: int, n_batch: int, k_batches: int,
                        seed: int = 0):
    """One corpus + k probe batches in a shared token universe, with planted
    cross-batch near-duplicates so every probe returns pairs."""
    rng = np.random.default_rng(seed)

    def draw(n):
        sizes = np.maximum(rng.poisson(12, size=n), 1)
        return [np.unique(rng.integers(0, 900, size=2 * sz + 8))[:sz].tolist()
                for sz in sizes]

    corpus_sets = draw(n_corpus)
    batch_sets = []
    for k in range(k_batches):
        sets = draw(n_batch)
        for i in range(min(n_batch // 10, n_corpus)):
            sets[i] = corpus_sets[(k * 37 + i) % n_corpus]
        batch_sets.append(sets)
    # One padded width across corpus and batches -> one jit cache for all
    # probe steps.
    width = max(len(s) for group in [corpus_sets] + batch_sets for s in group)
    corpus = from_lists(corpus_sets, pad_to=width)
    batches = [from_lists(sets, pad_to=width) for sets in batch_sets]
    return corpus, batches


def _assert_amortized(engine: JoinEngine) -> None:
    builds = engine.prepared.builds
    assert builds["sort"] == 1, builds
    assert builds["bitmap"] == 1, builds
    assert builds["window"] <= 1, builds


def run() -> List[Row]:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    n_corpus, n_batch, k = (600, 150, 3) if smoke else (3000, 500, 5)
    corpus, batches = _corpus_and_batches(n_corpus, n_batch, k)
    planner = JoinPlanner(b=B, block=2048, naive_cells=0)  # always 'blocked'
    rows: List[Row] = []

    # --- engine: prepare once, stream batches -----------------------------
    t0 = time.perf_counter()
    engine = JoinEngine(corpus, JACCARD, TAU, planner=planner)
    first_pairs, first_stats = engine.probe(batches[0])
    cold = time.perf_counter() - t0

    warm_times = []
    engine_pairs = [first_pairs]
    for batch in batches[1:] + [batches[0]]:
        t0 = time.perf_counter()
        pairs, _stats = engine.probe(batch)
        warm_times.append(time.perf_counter() - t0)
        engine_pairs.append(pairs)
    warm = sorted(warm_times)[len(warm_times) // 2]
    _assert_amortized(engine)  # probes 2..k never re-sorted or re-hashed R

    # --- rebuild-per-call: today's one-shot driver ------------------------
    rebuild_times = []
    for idx, batch in enumerate(batches):
        t0 = time.perf_counter()
        pairs = blocked_bitmap_join(corpus, batch, JACCARD, TAU,
                                    b=B, block=2048)
        rebuild_times.append(time.perf_counter() - t0)
        assert np.array_equal(pairs, engine_pairs[idx])
    rebuild = sorted(rebuild_times)[len(rebuild_times) // 2]

    oracle = naive_join(corpus, batches[0], JACCARD, TAU)
    assert np.array_equal(first_pairs, oracle)

    rows.append(Row(
        "engine_probe_cold", cold * 1e6,
        f"prepare+first_probe pairs={len(first_pairs)} "
        f"filter_ratio={first_stats.filter_ratio:.4f}",
        stats=first_stats.to_dict()))
    rows.append(Row(
        "engine_probe_warm", warm * 1e6,
        f"median_of_{len(warm_times)} rebuild_per_call={rebuild*1e6:.0f}us "
        f"amortized_speedup={rebuild/max(warm, 1e-9):.2f}x "
        f"builds={engine.prepared.builds}"))
    rows.append(Row(
        "engine_rebuild_per_call", rebuild * 1e6,
        f"one-shot blocked_bitmap_join (re-sorts + regenerates bitmaps)"))
    rows.extend(_indexed_vs_blocked(smoke))
    rows.extend(_ring_vs_sharded(smoke))
    return rows


def _indexed_vs_blocked(smoke: bool) -> List[Row]:
    """Indexed vs blocked on one skewed self-join: same exact pair set,
    candidate funnels recorded side by side in the trajectory JSON."""
    from repro.data.collections import skewed_collection, with_duplicates
    from repro.index import indexed_bitmap_join

    n = 1500 if smoke else 6000
    col = with_duplicates(  # planted clusters -> non-trivial pair equality
        skewed_collection(n_sets=n, avg_size=10, n_tokens=40_000, seed=3),
        n_clusters=n // 50, cluster_size=3, jaccard=0.9, seed=4)

    t0 = time.perf_counter()
    bpairs, bstats = blocked_bitmap_join(col, JACCARD, TAU, b=B, block=2048,
                                         return_stats=True)
    t_blocked = time.perf_counter() - t0
    t0 = time.perf_counter()
    ipairs, istats = indexed_bitmap_join(col, JACCARD, TAU, b=B,
                                         probe_block=2048, return_stats=True)
    t_indexed = time.perf_counter() - t0
    assert np.array_equal(bpairs, ipairs)

    cells_ratio = (istats.candidates_generated
                   / max(bstats.candidates_generated, 1))
    rows = [
        Row("engine_blocked_selfjoin", t_blocked * 1e6,
            f"n={n} pairs={len(bpairs)} "
            f"bitmap_cells={bstats.candidates_generated}",
            stats=bstats.to_dict()),
        Row("engine_indexed_selfjoin", t_indexed * 1e6,
            f"n={n} pairs={len(ipairs)} "
            f"bitmap_cells={istats.candidates_generated} "
            f"cells_vs_blocked={cells_ratio:.4f} "
            f"expanded={istats.postings_expanded}",
            stats=istats.to_dict()),
    ]
    return rows


def _ring_vs_sharded(smoke: bool) -> List[Row]:
    """Ring (dense grid sharding) vs sharded-indexed (postings sharding) on
    one mesh self-join: identical exact pair set, funnels side by side in
    the trajectory JSON.  Uses whatever devices the process has (one in the
    check.sh smoke; eight under the multidevice XLA_FLAGS harness)."""
    import jax

    from repro.core.join import ring_join_prepared
    from repro.data.collections import skewed_collection, with_duplicates
    from repro.distributed.sharded_index import sharded_indexed_join_prepared
    from repro.launch.mesh import make_mesh

    n = 800 if smoke else 4000
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev,), ("data",))
    col = with_duplicates(
        skewed_collection(n_sets=n, avg_size=10, n_tokens=30_000, seed=9),
        n_clusters=n // 50, cluster_size=3, jaccard=0.9, seed=10)
    prep = prepare(col)

    t0 = time.perf_counter()
    rpairs, counters, _ovf = ring_join_prepared(
        prep, mesh=mesh, axis="data", sim=JACCARD, tau=TAU, b=B,
        return_stats=True)
    t_ring = time.perf_counter() - t0
    t0 = time.perf_counter()
    spairs, sstats = sharded_indexed_join_prepared(
        prep, mesh=mesh, axis="data", sim=JACCARD, tau=TAU, b=B,
        probe_block=2048, return_stats=True)
    t_sharded = time.perf_counter() - t0
    assert np.array_equal(rpairs, spairs)

    nnz = int((prep.lengths > 0).sum())
    ring_cells = nnz * (nnz - 1) // 2  # the grid the ring sweep evaluates
    return [
        Row("engine_ring_selfjoin", t_ring * 1e6,
            f"n={n} devices={n_dev} pairs={len(rpairs)} "
            f"bitmap_cells={ring_cells} "
            f"candidates={int(np.asarray(counters)[:, 0].sum())}"),
        Row("engine_sharded_indexed_selfjoin", t_sharded * 1e6,
            f"n={n} devices={n_dev} pairs={len(spairs)} "
            f"bitmap_cells={sstats.candidates_generated} "
            f"cells_vs_ring={sstats.candidates_generated / max(ring_cells, 1):.4f} "
            f"expanded={sstats.postings_expanded}",
            stats=sstats.to_dict()),
    ]


def run_engine_smoke() -> List[Row]:
    """CI gate (``scripts/check.sh``): prepare once, probe twice, assert the
    second probe reuses the cached bitmap words and matches the oracle."""
    corpus, batches = _corpus_and_batches(300, 80, 1, seed=7)
    batch = batches[0]
    engine = JoinEngine(corpus, JACCARD, TAU,
                        planner=JoinPlanner(b=B, block=1024, naive_cells=0))
    prep_batch = prepare(batch)
    t0 = time.perf_counter()
    pairs1, _ = engine.probe(prep_batch)
    t1 = time.perf_counter() - t0
    builds_after_first = engine.prepared.build_counts()
    t0 = time.perf_counter()
    pairs2, stats2 = engine.probe(prep_batch)
    t2 = time.perf_counter() - t0
    # The second probe must not rebuild anything on either side...
    assert engine.prepared.build_counts() == builds_after_first, (
        builds_after_first, engine.prepared.build_counts())
    assert engine.prepared.builds["sort"] == 1
    assert engine.prepared.builds["bitmap"] == 1
    assert prep_batch.builds["bitmap"] == 1
    # ...and must return the oracle's exact pair set, like the first.
    oracle = naive_join(corpus, batch, JACCARD, TAU)
    assert np.array_equal(pairs1, oracle) and np.array_equal(pairs2, oracle)
    return [Row("engine_smoke_probe2", t2 * 1e6,
                f"probe1={t1*1e6:.0f}us pairs={len(pairs2)} "
                f"builds={engine.prepared.builds} OK",
                stats=stats2.to_dict())]


def run_indexed_smoke() -> List[Row]:
    """CI gate (``scripts/check.sh``): the indexed driver's engine contract.

    Prepare a corpus once, probe the same prepared batch twice through an
    ``"indexed"`` plan; the second probe must reuse the cached postings-CSR
    index, bitmap words and length sort (asserted via build counters) and
    both probes must return the exact oracle pair set.
    """
    corpus, batches = _corpus_and_batches(400, 100, 1, seed=11)
    batch = batches[0]
    plan = JoinPlan(driver="indexed", sim=JACCARD, tau=TAU, b=B, block=64)
    engine = JoinEngine(corpus, JACCARD, TAU, plan=plan)
    prep_batch = prepare(batch)
    t0 = time.perf_counter()
    pairs1, _ = engine.probe(prep_batch)
    t1 = time.perf_counter() - t0
    builds_after_first = engine.prepared.build_counts()
    assert builds_after_first["postings"] == 1, builds_after_first
    t0 = time.perf_counter()
    pairs2, stats2 = engine.probe(prep_batch)
    t2 = time.perf_counter() - t0
    # The second probe must not rebuild anything on either side...
    assert engine.prepared.build_counts() == builds_after_first, (
        builds_after_first, engine.prepared.build_counts())
    assert engine.prepared.builds["sort"] == 1
    assert engine.prepared.builds["bitmap"] == 1
    assert engine.prepared.builds["postings"] == 1
    assert prep_batch.builds["bitmap"] == 1
    assert prep_batch.builds["postings"] == 0  # index side is the corpus only
    # ...and must return the oracle's exact pair set, like the first.
    oracle = naive_join(corpus, batch, JACCARD, TAU)
    assert np.array_equal(pairs1, oracle) and np.array_equal(pairs2, oracle)
    assert (stats2.verified_true <= stats2.candidates
            <= stats2.candidates_generated == stats2.total_pairs)
    return [Row("indexed_smoke_probe2", t2 * 1e6,
                f"probe1={t1*1e6:.0f}us pairs={len(pairs2)} "
                f"builds={engine.prepared.builds} OK",
                stats=stats2.to_dict())]


def run_sharded_smoke() -> List[Row]:
    """CI gate (``scripts/check.sh``): the sharded-indexed engine contract.

    Prepare a corpus once, probe the same prepared batch twice through a
    ``"sharded-indexed"`` plan on a mesh over all available devices; the
    second probe must reuse the cached postings CSR *and* its token-slab
    partition (``builds["postings"] == builds["sharded_postings"] == 1``)
    and both probes must return the exact oracle pair set.
    """
    import jax

    from repro.launch.mesh import make_mesh

    corpus, batches = _corpus_and_batches(400, 100, 1, seed=13)
    batch = batches[0]
    mesh = make_mesh((jax.device_count(),), ("data",))
    plan = JoinPlan(driver="sharded-indexed", sim=JACCARD, tau=TAU, b=B,
                    block=64)
    engine = JoinEngine(corpus, JACCARD, TAU, plan=plan, mesh=mesh,
                        axis="data")
    prep_batch = prepare(batch)
    t0 = time.perf_counter()
    pairs1, _ = engine.probe(prep_batch)
    t1 = time.perf_counter() - t0
    builds_after_first = engine.prepared.build_counts()
    assert builds_after_first["postings"] == 1, builds_after_first
    assert builds_after_first["sharded_postings"] == 1, builds_after_first
    t0 = time.perf_counter()
    pairs2, stats2 = engine.probe(prep_batch)
    t2 = time.perf_counter() - t0
    # The second probe must not rebuild anything on either side...
    assert engine.prepared.build_counts() == builds_after_first, (
        builds_after_first, engine.prepared.build_counts())
    assert engine.prepared.builds["sort"] == 1
    assert engine.prepared.builds["bitmap"] == 1
    assert prep_batch.builds["sharded_postings"] == 0  # corpus side only
    # ...and must return the oracle's exact pair set, like the first.
    oracle = naive_join(corpus, batch, JACCARD, TAU)
    assert np.array_equal(pairs1, oracle) and np.array_equal(pairs2, oracle)
    assert (stats2.verified_true <= stats2.candidates
            <= stats2.candidates_generated == stats2.total_pairs)
    return [Row("sharded_smoke_probe2", t2 * 1e6,
                f"probe1={t1*1e6:.0f}us devices={len(mesh.devices.flat)} "
                f"pairs={len(pairs2)} builds={engine.prepared.builds} OK",
                stats=stats2.to_dict())]


if __name__ == "__main__":
    import sys

    argv = sys.argv[1:]
    if "--indexed-smoke" in argv:
        fn = run_indexed_smoke
    elif "--sharded-smoke" in argv:
        fn = run_sharded_smoke
    elif "--smoke" in argv:
        fn = run_engine_smoke
    else:
        fn = run
    print("name,us_per_call,derived")
    for r in fn():
        print(r.csv(), flush=True)
