"""Shared benchmark helpers: timing, cached collections, CSV rows.

Each bench module exposes ``run() -> list[Row]``; ``benchmarks/run.py`` prints
``name,us_per_call,derived`` per row.  Collections follow the paper's §5
methodology at a scale that keeps the full suite a few minutes on this CPU
(absolute times are not comparable to the paper's C++/GPU hardware — the
*relative* effects, which are the paper's claims, are).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, List, Optional

from repro.data.collections import (
    dblp_like_collection,
    uniform_collection,
    with_duplicates,
    zipf_collection,
)


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str
    stats: Optional[dict] = None  # e.g. JoinStats.to_dict() — emitted as JSON
    # Latency distribution (serving benches): round-tripped through the
    # trajectory JSON so the perf gate can gate tail latency, not just means.
    p50_us: Optional[float] = None
    p99_us: Optional[float] = None

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"

    def to_json(self) -> dict:
        d = {"name": self.name, "us_per_call": self.us_per_call,
             "derived": self.derived}
        if self.stats is not None:
            d["stats"] = self.stats
        if self.p50_us is not None:
            d["p50_us"] = self.p50_us
        if self.p99_us is not None:
            d["p99_us"] = self.p99_us
        return d


def timeit(fn: Callable, repeats: int = 3) -> float:
    """Median wall time of fn() in microseconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


@functools.lru_cache(maxsize=None)
def collection(name: str, n: int = 2000):
    if name == "uniform":
        return uniform_collection(n_sets=n, avg_size=10, n_tokens=220, seed=0)
    if name == "zipf":
        return zipf_collection(n_sets=n, avg_size=50, n_tokens=101_584, seed=0)
    if name == "dblp":
        return dblp_like_collection(n_sets=max(n // 2, 500), seed=0)
    if name == "dupes":
        base = uniform_collection(n_sets=n, avg_size=12, n_tokens=500, seed=1)
        return with_duplicates(base, n_clusters=n // 50, cluster_size=3,
                               jaccard=0.9, seed=2)
    raise KeyError(name)


COLLECTIONS = ("uniform", "zipf", "dblp")
THRESHOLDS = (0.5, 0.6, 0.7, 0.75, 0.8, 0.85, 0.9, 0.95)
