"""Paper Fig. 6-7 + Algorithm 6 — cutoff points and combined crossovers."""

from __future__ import annotations

import time
from typing import List

from benchmarks.common import Row
from repro.core import expected
from repro.core.constants import BITMAP_NEXT, BITMAP_SET, BITMAP_XOR


def run() -> List[Row]:
    rows: List[Row] = []
    t0 = time.perf_counter()
    cs = expected.cutoff_point(BITMAP_SET, 1024, 0.9)
    cx = expected.cutoff_point(BITMAP_XOR, 1024, 0.9)
    dt = (time.perf_counter() - t0) * 1e6 / 2
    rows.append(Row("fig6_cutoff_b1024_tau0.9", dt,
                    f"set={cs} (paper 2129) xor={cx} (paper 4983) "
                    f"ratio={cx/cs:.2f} (paper 2.3x)"))
    r8 = expected.cutoff_point(BITMAP_XOR, 1024, 0.8) / expected.cutoff_point(
        BITMAP_SET, 1024, 0.8)
    rows.append(Row("fig6_cutoff_ratio_tau0.8", 0.0,
                    f"xor/set={r8:.3f} (paper 1.47x)"))
    t0 = time.perf_counter()
    lo, hi = expected.combined_crossovers_normalized(64)
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(Row("alg6_combined_crossovers_b64", dt,
                    f"next<= {lo:.3f} (paper 0.56)  xor>= {hi:.3f} (paper 0.73)"))
    for b in (256, 1024, 4096):
        lo, hi = expected.combined_crossovers_normalized(b)
        rows.append(Row(f"alg6_crossovers_b{b}", 0.0,
                        f"lo={lo:.3f} hi={hi:.3f} (paper: 'same pattern for any b>=64')"))
    return rows
