"""Kernel micro-benchmarks: pure-jnp filter throughput on this CPU plus the
analytic TPU roofline of the two Pallas kernels (SWAR/VPU vs MXU bit-plane),
which is how the §Perf kernel choice was made."""

from __future__ import annotations

import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Row, timeit
from repro.kernels import ops as kops

# TPU v5e-class constants (assignment)
PEAK_MXU_INT8 = 394e12   # int8 ops/s
PEAK_VPU = 4e12          # rough vector int ops/s (8x128 x 8 ALUs x ~1GHz x cores)
HBM_BW = 819e9


def run() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    n, m = 2048, 2048
    for b in (64, 256, 1024, 4096):
        w = b // 32
        wr = jnp.asarray(rng.integers(0, 2**32, size=(n, w), dtype=np.uint32))
        ws = jnp.asarray(rng.integers(0, 2**32, size=(m, w), dtype=np.uint32))
        fn = jax.jit(lambda a, bb: kops.hamming_matrix(a, bb, impl="ref"))
        fn(wr, ws).block_until_ready()
        us = timeit(lambda: fn(wr, ws).block_until_ready())
        pairs_per_s = n * m / (us / 1e6)
        # analytic per-pair cost on TPU:
        #   SWAR: ~6 VPU ops per 32-bit word -> 6*w ops/pair
        #   MXU : 2*b int8 MACs/pair (+ O(n*b) unpack amortised)
        t_swar = 6 * w / PEAK_VPU
        t_mxu = 2 * b / PEAK_MXU_INT8
        t_mem = (2 * w * 4) / HBM_BW  # stream both bitmaps once per tile row
        rows.append(Row(
            f"kernel_hamming_b{b}", us,
            f"cpu_pairs_per_s={pairs_per_s:.2e} "
            f"tpu_roofline_pairs_per_s: swar={1/t_swar:.2e} mxu={1/t_mxu:.2e} "
            f"pref={'mxu' if t_mxu < t_swar else 'swar'}"))
    return rows
