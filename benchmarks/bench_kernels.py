"""Kernel micro-benchmarks with measured rooflines (ROADMAP "as fast as the
hardware allows").

Each hot-path kernel row is timed on this backend AND analyzed through the
compiled-HLO cost machinery (``launch/hlo_analysis.analyze`` →
``launch/roofline.kernel_roofline``), so every row carries achieved-vs-peak
bytes + flops and the bottleneck term next to ``us_per_call``:

* ``kernel_pair_verdict_*`` — the indexed driver's per-candidate bitmap
  verdict (the GPGPU verification-phase study, arXiv:1812.09141, shows this
  becomes the bottleneck once candidate generation is sub-quadratic);
* ``kernel_entry_filter_*`` — the per-posting admission filter;
* ``kernel_indexed_chunk_*`` — the whole fused expand→filter→dedup→verdict→
  verify chunk step of ``index/candidates.py``;
* ``kernel_hamming_*`` — the dense all-pairs kernel, with the analytic
  SWAR-vs-MXU preference that motivated ``impl='auto'`` dispatch.

These rows are the perf-regression gate's input: ``benchmarks/perf_gate.py``
compares their ``us_per_call`` against the previous trajectory entry and
fails ``scripts/check.sh`` on >1.3x regressions.  Row names embed the shape,
so smoke (small) and full (large) runs never gate against each other.

The achieved/peak fractions use the TPU v5e-class constants of
``launch/roofline.py``; on this CPU container they are tiny by construction
— the trajectory tracks the *relative* movement and the bottleneck term.
Note the SWAR kernels have zero HLO dot-FLOPs (XOR+popcount is elementwise),
so their roofline is purely the memory term; only the bit-plane MXU
formulation turns the verdict into dot FLOPs.
"""

from __future__ import annotations

import os
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Row, timeit
from repro.launch.hlo_analysis import analyze
from repro.launch.roofline import kernel_roofline
from repro.kernels import ops as kops

# TPU v5e-class constants for the analytic SWAR-vs-MXU preference note.
PEAK_MXU_INT8 = 394e12   # int8 ops/s
PEAK_VPU = 4e12          # rough vector int ops/s (8x128 x 8 ALUs x ~1GHz x cores)
HBM_BW = 819e9


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


def _measured_roofline_row(name: str, lowered, args, extra: str = "") -> Row:
    """Compile, time, HLO-analyze one kernel; emit the roofline columns."""
    comp = lowered.compile()
    jax.block_until_ready(comp(*args))
    # These rows feed the 1.3x regression gate — median of 9 runs keeps the
    # wall-clock jitter of a shared CPU container well under the threshold.
    us = timeit(lambda: jax.block_until_ready(comp(*args)), repeats=9)
    kr = kernel_roofline(name, analyze(comp.as_text()), us)
    derived = (extra + " " if extra else "") + kr.columns()
    return Row(name, us, derived, stats={"roofline": kr.as_dict()})


def _pair_verdict_rows(rng, gs: int, bs) -> List[Row]:
    rows = []
    for b in bs:
        w = b // 32
        wr = jnp.asarray(rng.integers(0, 2**32, size=(gs, w), dtype=np.uint32))
        ws = jnp.asarray(rng.integers(0, 2**32, size=(gs, w), dtype=np.uint32))
        lr = jnp.asarray(rng.integers(1, 40, size=gs, dtype=np.int32))
        ls = jnp.asarray(rng.integers(1, 40, size=gs, dtype=np.int32))
        low = kops.pair_verdict.lower(wr, ws, lr, ls, sim="jaccard", tau=0.8,
                                      cutoff=1 << 30, impl="ref")
        # Analytic per-candidate note: word-loop SWAR vs candidate-major
        # tiled stream vs batched bit-plane MXU (what impl='auto' picks on
        # TPU: swar_tiled below 512 bits, mxu at or above).
        t_swar = 6 * w / PEAK_VPU
        t_mxu = 2 * b / PEAK_MXU_INT8
        pref = "mxu" if t_mxu < t_swar else "swar_tiled"
        rows.append(_measured_roofline_row(
            f"kernel_pair_verdict_b{b}_g{gs}", low, (wr, ws, lr, ls),
            extra=f"tpu_pref={pref}"))
    return rows


def _entry_filter_rows(rng, gs: int) -> List[Row]:
    args = (
        jnp.asarray(rng.integers(0, 40, size=gs, dtype=np.int32)),  # len_r
        jnp.asarray(rng.integers(0, 8, size=gs, dtype=np.int32)),   # pos_r
        jnp.asarray(rng.integers(0, 40, size=gs, dtype=np.int32)),  # len_s
        jnp.asarray(rng.integers(0, 8, size=gs, dtype=np.int32)),   # pos_s
        jnp.asarray(rng.integers(0, 20, size=gs, dtype=np.int32)),  # lo
        jnp.asarray(rng.integers(10, 40, size=gs, dtype=np.int32)), # hi
        jnp.asarray(rng.integers(0, 10_000, size=gs, dtype=np.int32)),
        jnp.asarray(rng.integers(0, 10_000, size=gs, dtype=np.int32)),
        jnp.asarray(rng.random(gs) > 0.1),                          # valid
    )
    low = kops.entry_filter.lower(*args, sim="jaccard", tau=0.8,
                                  self_join=False, impl="ref")
    return [_measured_roofline_row(f"kernel_entry_filter_g{gs}", low, args)]


def _indexed_chunk_rows(rng, n: int, probe_block: int) -> List[Row]:
    from repro.core.collection import from_lists
    from repro.core.engine import prepare
    from repro.index.candidates import _indexed_chunk_step, chunk_step_spec

    sets = [rng.choice(n // 2, size=rng.integers(2, 14), replace=False).tolist()
            for _ in range(n)]
    prep = prepare(from_lists(sets, pad_to=16))
    args, statics = chunk_step_spec(prep, sim="jaccard", tau=0.8,
                                    probe_block=probe_block)
    low = _indexed_chunk_step.lower(*args, **statics)
    return [_measured_roofline_row(
        f"kernel_indexed_chunk_n{n}_pb{probe_block}", low, args,
        extra=f"cap={statics['cap']}")]


def _hamming_rows(rng, n: int, bs) -> List[Row]:
    rows = []
    for b in bs:
        w = b // 32
        wr = jnp.asarray(rng.integers(0, 2**32, size=(n, w), dtype=np.uint32))
        ws = jnp.asarray(rng.integers(0, 2**32, size=(n, w), dtype=np.uint32))
        low = kops.hamming_matrix.lower(wr, ws, impl="ref")
        t_swar = 6 * w / PEAK_VPU
        t_mxu = 2 * b / PEAK_MXU_INT8
        rows.append(_measured_roofline_row(
            f"kernel_hamming_b{b}_n{n}", low, (wr, ws),
            extra=("tpu_roofline_pairs_per_s: "
                   f"swar={1/t_swar:.2e} mxu={1/t_mxu:.2e} "
                   f"pref={'mxu' if t_mxu < t_swar else 'swar'}")))
    return rows


def run() -> List[Row]:
    rng = np.random.default_rng(0)
    rows: List[Row] = []
    if _smoke():
        rows += _pair_verdict_rows(rng, gs=1 << 14, bs=(128,))
        rows += _entry_filter_rows(rng, gs=1 << 17)
        rows += _indexed_chunk_rows(rng, n=600, probe_block=512)
        rows += _hamming_rows(rng, n=512, bs=(256,))
    else:
        rows += _pair_verdict_rows(rng, gs=1 << 16, bs=(64, 256, 1024))
        rows += _entry_filter_rows(rng, gs=1 << 18)
        rows += _indexed_chunk_rows(rng, n=2000, probe_block=1024)
        rows += _hamming_rows(rng, n=2048, bs=(64, 256, 1024, 4096))
    return rows
