"""R×S two-collection join vs self-join: throughput and filter ratios.

The paper defines the join over two collections R and S; this benchmark
measures (a) the blocked device join on an R×S workload vs a self-join over
R ∪ S of the same total size (the R×S walk visits |R|·|S| block pairs instead
of (|R|+|S|)²/2 — the win of knowing the problem is bipartite), and (b) the
bitmap filter ratio on both, which Table 9's effectiveness claim extends to
the two-collection case.  A PPJoin R×S run anchors the CPU side.
"""

from __future__ import annotations

import os
import time
from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core import cpu_algos, join
from repro.core.collection import Collection, from_lists, preprocess_rs
from repro.core.constants import PAD_TOKEN
from repro.core.filters import BitmapFilter

TAUS = (0.5, 0.7, 0.9)


def _two_shards(n_r: int, n_s: int, seed: int = 0):
    """Two raw shards in one token universe, relabelled with the shared
    frequency order (per-collection `preprocess` would split the order)."""
    rng = np.random.default_rng(seed)

    def draw(n):
        sizes = np.maximum(rng.poisson(12, size=n), 1)
        return [np.unique(rng.integers(0, 800, size=2 * sz + 8))[:sz].tolist()
                for sz in sizes]

    sets_r = draw(n_r)
    sets_s = draw(n_s)
    # plant cross-shard near-dups so result sets are non-trivial
    for k in range(min(n_s // 20, len(sets_r))):
        sets_s[k] = sets_r[k]
    return preprocess_rs(from_lists(sets_r), from_lists(sets_s))


def _concat(col_r: Collection, col_s: Collection) -> Collection:
    width = max(col_r.max_len, col_s.max_len)

    def pad(c):
        t = np.full((c.num_sets, width), PAD_TOKEN, dtype=c.tokens.dtype)
        t[:, :c.max_len] = c.tokens
        return t

    return Collection(tokens=np.concatenate([pad(col_r), pad(col_s)]),
                      lengths=np.concatenate([col_r.lengths, col_s.lengths]))


def run() -> List[Row]:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    n_r, n_s = (400, 200) if smoke else (2000, 1000)
    col_r, col_s = _two_shards(n_r, n_s)
    both = _concat(col_r, col_s)
    rows: List[Row] = []
    for tau in TAUS:
        # warm (compile) then measure
        join.blocked_bitmap_join(col_r, col_s, "jaccard", tau, b=128, block=2048)
        t0 = time.perf_counter()
        rs_pairs, rs_stats = join.blocked_bitmap_join(
            col_r, col_s, "jaccard", tau, b=128, block=2048, return_stats=True)
        rs_t = time.perf_counter() - t0

        # device-resident compaction: same join, no dense host transfer
        join.blocked_bitmap_join(col_r, col_s, "jaccard", tau, b=128,
                                 block=2048, compaction="device")
        t0 = time.perf_counter()
        res_pairs, res_stats = join.blocked_bitmap_join(
            col_r, col_s, "jaccard", tau, b=128, block=2048,
            compaction="device", return_stats=True)
        res_t = time.perf_counter() - t0
        assert len(res_pairs) == len(rs_pairs)  # both exact

        join.blocked_bitmap_join(both, "jaccard", tau, b=128, block=2048)
        t0 = time.perf_counter()
        _, self_stats = join.blocked_bitmap_join(
            both, "jaccard", tau, b=128, block=2048, return_stats=True)
        self_t = time.perf_counter() - t0

        bf = BitmapFilter.build_rs(col_r.tokens, col_r.lengths,
                                   col_s.tokens, col_s.lengths,
                                   "jaccard", tau, b=128)
        t0 = time.perf_counter()
        cpu_algos.ppjoin(col_r, col_s, "jaccard", tau, bitmap=bf)
        cpu_t = time.perf_counter() - t0

        rows.append(Row(
            f"rs_join_device_tau{tau}", rs_t * 1e6,
            f"pairs={len(rs_pairs)} filter_ratio={rs_stats.filter_ratio:.4f} "
            f"self_join_RuS={self_t*1e6:.0f}us "
            f"self_filter_ratio={self_stats.filter_ratio:.4f}",
            stats=rs_stats.to_dict()))
        rows.append(Row(
            f"rs_join_resident_tau{tau}", res_t * 1e6,
            f"pairs={len(res_pairs)} host_compaction={rs_t*1e6:.0f}us "
            f"overflow_blocks={res_stats.overflow_blocks}",
            stats=res_stats.to_dict()))
        rows.append(Row(
            f"rs_join_ppjoin_bf_tau{tau}", cpu_t * 1e6,
            f"device_speedup={cpu_t/max(rs_t, 1e-9):.2f}x"))
    return rows


def run_resident_smoke() -> List[Row]:
    """Compaction-path smoke gate (``python -m benchmarks.bench_rs_join
    --resident``): a shrunk R×S workload through the device-resident join,
    asserting it reproduces the host-compaction pair set exactly."""
    import numpy as np

    col_r, col_s = _two_shards(300, 150)
    rows: List[Row] = []
    for tau in (0.5, 0.8):
        host = join.blocked_bitmap_join(col_r, col_s, "jaccard", tau,
                                        b=128, block=1024)
        join.blocked_bitmap_join(col_r, col_s, "jaccard", tau, b=128,
                                 block=1024, compaction="device")  # warm
        t0 = time.perf_counter()
        res, stats = join.blocked_bitmap_join(
            col_r, col_s, "jaccard", tau, b=128, block=1024,
            compaction="device", return_stats=True)
        dt = time.perf_counter() - t0
        assert np.array_equal(host, res), f"resident != host at tau={tau}"
        rows.append(Row(
            f"rs_join_resident_smoke_tau{tau}", dt * 1e6,
            f"pairs={len(res)} filter_ratio={stats.filter_ratio:.4f} "
            f"overflow_blocks={stats.overflow_blocks}",
            stats=stats.to_dict()))
    return rows


if __name__ == "__main__":
    import sys

    fn = run_resident_smoke if "--resident" in sys.argv[1:] else run
    print("name,us_per_call,derived")
    for r in fn():
        print(r.csv(), flush=True)
