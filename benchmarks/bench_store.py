"""Appendable corpus store: append latency, delta-fraction probe tax,
compaction amortization.

The store's value proposition is quantitative: ``append`` must cost the
delta's preparation only (vs re-preparing the whole corpus), probes must
degrade gracefully as the delta fraction grows (each delta adds one small
segment join), and a compaction must cost about one rebuild while returning
the probe path to its sealed-base speed.  All three claims are measured —
and the build-counter contracts behind them asserted — here.

Rows:

* ``store_append_delta`` — µs per ``append()`` (prepares only the delta;
  perf-gated).
* ``store_probe_f00 / f10 / f30`` — probe µs at 0% / ~10% / ~30% delta
  fraction (same batch, same corpus content; perf-gated) — the price of
  liveness before the compaction policy folds it back.
* ``store_compact_fold`` — one compaction folding every delta into a new
  sealed base, with the post-compaction probe returning to f00 speed.

``python -m benchmarks.bench_store --smoke`` runs the CI gate flavour
(``scripts/check.sh``): N appends never rebuild the base (builds counters),
and the post-compaction store is bit-identical — pairs and summed funnel
stats — to a from-scratch rebuild.
"""

from __future__ import annotations

import os
import time
from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core import JACCARD, JoinEngine, prepare
from repro.core.collection import from_lists
from repro.core.plan import JoinPlan
from repro.store import CompactionPolicy, CorpusStore

TAU = 0.8
B = 128


def _sets(rng, n, universe=900):
    sizes = np.maximum(rng.poisson(12, size=n), 1)
    return [np.unique(rng.integers(0, universe, size=2 * sz + 8))[:sz].tolist()
            for sz in sizes]


def _workload(n_corpus: int, n_delta: int, k_deltas: int, n_batch: int,
              seed: int = 0):
    """Corpus + deltas + one probe batch in a shared token universe, with
    planted corpus rows in the batch and deltas so every join is
    non-trivial.  One padded width -> one jit cache for every segment."""
    rng = np.random.default_rng(seed)
    corpus_sets = _sets(rng, n_corpus)
    delta_sets = []
    for k in range(k_deltas):
        sets = _sets(rng, n_delta)
        for i in range(min(n_delta // 8, n_corpus)):
            sets[i] = corpus_sets[(k * 31 + i) % n_corpus]
        delta_sets.append(sets)
    batch_sets = _sets(rng, n_batch)
    for i in range(min(n_batch // 5, n_corpus)):
        batch_sets[i] = corpus_sets[(7 * i) % n_corpus]
    width = max(len(s) for group in
                [corpus_sets, batch_sets] + delta_sets for s in group)
    return (from_lists(corpus_sets, pad_to=width),
            [from_lists(s, pad_to=width) for s in delta_sets],
            from_lists(batch_sets, pad_to=width))


def _plan():
    return JoinPlan(driver="blocked", sim=JACCARD, tau=TAU, b=B, block=2048)


def _median_probe(store, batch, repeats=3) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        store.probe(batch)
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def run() -> List[Row]:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    n_corpus, n_delta, n_batch = (600, 30, 100) if smoke else (3000, 150, 400)
    k = 6  # 3 deltas to ~10% fraction, 3 more to ~30%
    corpus, deltas, batch = _workload(n_corpus, n_delta, k, n_batch)
    rows: List[Row] = []

    store = CorpusStore(corpus, JACCARD, TAU, plan=_plan(),
                        policy=CompactionPolicy.never())
    t_f00 = _median_probe(store, batch)  # sealed-base baseline (warm jit)
    base_builds = store.builds()

    # --- append: prepares only the delta ----------------------------------
    append_times = []
    for delta in deltas[:3]:
        t0 = time.perf_counter()
        store.append(delta, compact=False)
        append_times.append(time.perf_counter() - t0)
    t_append = sorted(append_times)[len(append_times) // 2]
    assert store.builds() == base_builds, (store.builds(), base_builds)
    t_f10 = _median_probe(store, batch)
    f10 = store.stats().delta_fraction

    for delta in deltas[3:]:
        store.append(delta, compact=False)
    assert store.builds() == base_builds
    t_f30 = _median_probe(store, batch)
    f30 = store.stats().delta_fraction

    # --- compaction: one merge buys back the sealed-base probe ------------
    t0 = time.perf_counter()
    store.compact()
    t_compact = time.perf_counter() - t0
    t_post = _median_probe(store, batch)

    # Amortization frame: a rebuild-per-append regime prepares the whole
    # corpus k times; the store prepared k deltas + one merge.
    t0 = time.perf_counter()
    prepare(store.collection()).bitmap_words(B, "combined", tau=TAU)
    t_rebuild = time.perf_counter() - t0

    rows.append(Row(
        "store_append_delta", t_append * 1e6,
        f"n_delta={n_delta} per_doc={t_append * 1e6 / n_delta:.1f}us "
        f"full_rebuild={t_rebuild * 1e6:.0f}us "
        f"rebuild_ratio={t_rebuild / max(t_append, 1e-9):.1f}x"))
    rows.append(Row(
        "store_probe_f00", t_f00 * 1e6,
        f"n={n_corpus} batch={n_batch} sealed base, delta_fraction=0"))
    rows.append(Row(
        "store_probe_f10", t_f10 * 1e6,
        f"delta_fraction={f10:.3f} segments=4 "
        f"tax={t_f10 / max(t_f00, 1e-9):.2f}x"))
    rows.append(Row(
        "store_probe_f30", t_f30 * 1e6,
        f"delta_fraction={f30:.3f} segments=7 "
        f"tax={t_f30 / max(t_f00, 1e-9):.2f}x"))
    rows.append(Row(
        "store_compact_fold", t_compact * 1e6,
        f"folded {k} deltas ({k * n_delta} rows) into base "
        f"post_probe={t_post * 1e6:.0f}us "
        f"vs_one_rebuild={t_compact / max(t_rebuild, 1e-9):.2f}x"))
    return rows


def run_store_smoke() -> List[Row]:
    """CI gate (``scripts/check.sh``): across N appends the base is never
    rebuilt (builds counters), and after a compaction the store is
    bit-identical — pairs and summed funnel stats — to a from-scratch
    rebuild of the same rows."""
    corpus, deltas, batch = _workload(300, 40, 3, 80, seed=7)
    plan = JoinPlan(driver="blocked", sim=JACCARD, tau=TAU, b=B, block=1024)
    store = CorpusStore(corpus, JACCARD, TAU, plan=plan,
                        policy=CompactionPolicy.never())
    pairs0, _ = store.probe(batch)          # builds the base artifacts
    base_builds = store.builds()
    assert base_builds["sort"] == 1 and base_builds["bitmap"] == 1

    for delta in deltas:
        store.append(delta, compact=False)
        store.probe(batch)
    # N appends: the sealed base was never re-sorted or re-hashed.
    assert store.builds() == base_builds, (store.builds(), base_builds)
    assert store.stats().delta_count == len(deltas)

    live_pairs, live_stats = store.probe(batch)
    t0 = time.perf_counter()
    assert store.compact()
    t_compact = time.perf_counter() - t0
    assert store.builds()["sort"] == 1      # a fresh base, built once
    post_pairs, post_stats = store.probe(batch)

    # Post-compaction bit-identity vs a from-scratch rebuild.
    oracle = JoinEngine(prepare(store.collection()), JACCARD, TAU, plan=plan)
    opairs, ostats = oracle.probe(batch)
    assert np.array_equal(post_pairs, opairs)
    assert np.array_equal(live_pairs, opairs)   # ...and pre-compaction too
    for f in ("total_pairs", "candidates", "verified_true",
              "candidates_generated", "postings_expanded"):
        assert getattr(post_stats, f) == getattr(ostats, f), f
        assert getattr(live_stats, f) == getattr(ostats, f), f
    s = store.stats()
    assert s.compactions == 1 and s.delta_count == 0
    return [Row("store_smoke_compact", t_compact * 1e6,
                f"appends={s.appends} pairs={len(post_pairs)} "
                f"lifetime_builds={s.lifetime_builds} OK",
                stats=post_stats.to_dict())]


if __name__ == "__main__":
    import sys

    fn = run_store_smoke if "--smoke" in sys.argv[1:] else run
    print("name,us_per_call,derived")
    for r in fn():
        print(r.csv(), flush=True)
