"""Paper Fig. 10 — filter ratio per bitmap generation method (b=64, no
cutoff).  Validates 'Bitmap-Xor consistently best at tau_j >= 0.5'."""

from __future__ import annotations

import time
from typing import List

from benchmarks.common import Row, collection
from repro.core import cpu_algos
from repro.core.filters import BitmapFilter
from repro.core.constants import BITMAP_METHODS

TAUS = (0.5, 0.7, 0.9)


def run() -> List[Row]:
    rows: List[Row] = []
    col = collection("dupes", 1500)
    for tau in TAUS:
        ratios = {}
        for method in BITMAP_METHODS:
            bf = BitmapFilter.build(col.tokens, col.lengths, "jaccard", tau,
                                    b=64, method=method, use_cutoff=False)
            stats = cpu_algos.AlgoStats()
            t0 = time.perf_counter()
            cpu_algos.allpairs(col, "jaccard", tau, bitmap=bf, stats=stats)
            dt = (time.perf_counter() - t0) * 1e6
            ratios[method] = stats.bitmap_pruned / max(stats.candidates, 1)
        best = max(ratios, key=ratios.get)
        rows.append(Row(
            f"fig10_method_ratio_tau{tau}", dt,
            " ".join(f"{m}={r:.3f}" for m, r in ratios.items())
            + f" best={best} (paper: xor for tau_j>=0.5)"))
    return rows
