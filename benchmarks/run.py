"""Benchmark driver — one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [module-substring ...]
Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import sys
import time

MODULES = [
    "benchmarks.bench_expected_bounds",    # Fig. 5 / Eq. 4-6
    "benchmarks.bench_cutoffs",            # Fig. 6-7 / Alg. 6
    "benchmarks.bench_cpu_algos",          # Tables 5-8
    "benchmarks.bench_filter_ratio",       # Table 9
    "benchmarks.bench_generation_methods", # Fig. 10
    "benchmarks.bench_precision",          # Fig. 11
    "benchmarks.bench_device_join",        # Table 10
    "benchmarks.bench_kernels",            # kernel roofline (DESIGN §6)
]


def main() -> None:
    import importlib

    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    print("name,us_per_call,derived")
    t_all = time.time()
    for modname in MODULES:
        if filters and not any(f in modname for f in filters):
            continue
        t0 = time.time()
        mod = importlib.import_module(modname)
        for row in mod.run():
            print(row.csv(), flush=True)
        print(f"# {modname} done in {time.time()-t0:.1f}s", flush=True)
    print(f"# total {time.time()-t_all:.1f}s")


if __name__ == "__main__":
    main()
