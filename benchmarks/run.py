"""Benchmark driver — one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--smoke] [module-substring ...]
Prints ``name,us_per_call,derived`` CSV rows.

``--smoke`` runs a fast subset (and tells modules that honour
``REPRO_BENCH_SMOKE`` to shrink their collections) — used by
``scripts/check.sh`` as a does-the-benchmark-stack-still-run gate.
"""

from __future__ import annotations

import os
import sys
import time

MODULES = [
    "benchmarks.bench_expected_bounds",    # Fig. 5 / Eq. 4-6
    "benchmarks.bench_cutoffs",            # Fig. 6-7 / Alg. 6
    "benchmarks.bench_cpu_algos",          # Tables 5-8
    "benchmarks.bench_filter_ratio",       # Table 9
    "benchmarks.bench_generation_methods", # Fig. 10
    "benchmarks.bench_precision",          # Fig. 11
    "benchmarks.bench_device_join",        # Table 10
    "benchmarks.bench_rs_join",            # R×S vs self-join
    "benchmarks.bench_kernels",            # kernel roofline (DESIGN §6)
]

SMOKE_MODULES = [
    "benchmarks.bench_expected_bounds",
    "benchmarks.bench_rs_join",
]


def main() -> None:
    import importlib

    smoke = "--smoke" in sys.argv[1:]
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    modules = SMOKE_MODULES if smoke and not filters else MODULES
    if smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    print("name,us_per_call,derived")
    t_all = time.time()
    for modname in modules:
        if filters and not any(f in modname for f in filters):
            continue
        t0 = time.time()
        mod = importlib.import_module(modname)
        for row in mod.run():
            print(row.csv(), flush=True)
        print(f"# {modname} done in {time.time()-t0:.1f}s", flush=True)
    print(f"# total {time.time()-t_all:.1f}s")


if __name__ == "__main__":
    main()
