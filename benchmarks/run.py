"""Benchmark driver — one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--smoke] [--json PATH]
                                               [--trajectory[=PATH]]
                                               [module-substring ...]
Prints ``name,us_per_call,derived`` CSV rows.

``--smoke`` runs a fast subset (and tells modules that honour
``REPRO_BENCH_SMOKE`` to shrink their collections) — used by
``scripts/check.sh`` as a does-the-benchmark-stack-still-run gate.

``--json PATH`` additionally writes every row (including any attached
``JoinStats`` dict — counters, filter_ratio, precision, overflow_blocks) to
PATH as a JSON list, so perf/filter-ratio trajectories can be diffed across
PRs instead of eyeballing CSV.

``--trajectory[=PATH]`` *appends* one summary entry (timestamp, git
revision, row list with stats) to the JSON list at PATH — the cross-PR perf
trajectory that ``benchmarks/perf_gate.py`` gates on.  An explicit path must
use the ``--trajectory=PATH`` form; bare ``--trajectory`` (or an empty
``--trajectory=``) resolves to the newest repo-root ``BENCH_PR*.json``
(:func:`default_trajectory`), and any following tokens are ordinary module
filters — bare ``--trajectory bench_engine`` filters to the engine bench
rather than writing a file named ``bench_engine``.
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os
import re
import subprocess
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MODULES = [
    "benchmarks.bench_expected_bounds",    # Fig. 5 / Eq. 4-6
    "benchmarks.bench_cutoffs",            # Fig. 6-7 / Alg. 6
    "benchmarks.bench_cpu_algos",          # Tables 5-8
    "benchmarks.bench_filter_ratio",       # Table 9
    "benchmarks.bench_generation_methods", # Fig. 10
    "benchmarks.bench_precision",          # Fig. 11
    "benchmarks.bench_device_join",        # Table 10
    "benchmarks.bench_rs_join",            # R×S vs self-join
    "benchmarks.bench_engine",             # prepared-vs-rebuild amortization
    "benchmarks.bench_kernels",            # kernel rooflines (perf gate rows)
    "benchmarks.bench_serve",              # online serving (coalesced probes)
    "benchmarks.bench_store",              # appendable corpus store (LSM)
]

SMOKE_MODULES = [
    "benchmarks.bench_expected_bounds",
    "benchmarks.bench_rs_join",
    "benchmarks.bench_engine",
    "benchmarks.bench_kernels",
    "benchmarks.bench_serve",
    "benchmarks.bench_store",
]


def default_trajectory() -> str:
    """Newest repo-root ``BENCH_PR*.json`` — so neither this file nor
    ``check.sh`` needs a manual path bump every PR.  A repo with no
    trajectory yet starts one at ``BENCH_PR0.json``."""
    found = []
    for p in glob.glob(os.path.join(_REPO_ROOT, "BENCH_PR*.json")):
        m = re.fullmatch(r"BENCH_PR(\d+)\.json", os.path.basename(p))
        if m:
            found.append((int(m.group(1)), p))
    if found:
        return max(found)[1]
    return os.path.join(_REPO_ROOT, "BENCH_PR0.json")


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def append_trajectory(path: str, rows, *, smoke: bool) -> int:
    """Append one run summary to the JSON trajectory list at ``path``.

    The file holds a list of entries ``{ts, rev, smoke, rows}``.  A corrupt
    or non-list file is moved aside to ``path + '.corrupt'`` (with a warning)
    and a fresh history started — never silently deleted: the trajectory is
    the cross-PR perf history the regression gate runs on.  Returns the new
    length.
    """
    history = []
    if os.path.exists(path):
        corrupt = None
        try:
            with open(path) as f:
                loaded = json.load(f)
            if isinstance(loaded, list):
                history = loaded
            else:
                corrupt = f"not a list ({type(loaded).__name__})"
        except (json.JSONDecodeError, OSError) as e:
            corrupt = str(e)
        if corrupt is not None:
            aside = path + ".corrupt"
            os.replace(path, aside)
            print(f"# WARNING: trajectory {path} unreadable ({corrupt}); "
                  f"moved aside to {aside}, starting fresh history",
                  file=sys.stderr)
    history.append({
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "rev": _git_rev(),
        "smoke": smoke,
        "rows": [r.to_json() for r in rows],
    })
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=1)
    os.replace(tmp, path)
    return len(history)


@dataclasses.dataclass
class Args:
    smoke: bool = False
    json_path: str | None = None
    trajectory_path: str | None = None
    filters: list[str] = dataclasses.field(default_factory=list)


def parse_args(argv: list[str]) -> Args:
    """CLI parsing, extracted so the ``--trajectory`` forms are testable.

    ``--trajectory`` never consumes the next token: explicit paths must use
    ``--trajectory=PATH`` (empty value → default), so following non-flag
    tokens always act as module filters.
    """
    args = Args()
    rest = []
    it = iter(argv)
    for a in it:
        if a == "--smoke":
            args.smoke = True
        elif a == "--json":
            try:
                args.json_path = next(it)
            except StopIteration:
                raise SystemExit("--json needs a path argument")
        elif a.startswith("--json="):
            args.json_path = a.split("=", 1)[1]
        elif a == "--trajectory":
            args.trajectory_path = default_trajectory()
        elif a.startswith("--trajectory="):
            args.trajectory_path = a.split("=", 1)[1] or default_trajectory()
        elif a.startswith("-"):
            raise SystemExit(f"unknown flag {a!r}")
        else:
            rest.append(a)
    args.filters = rest
    return args


def main() -> None:
    import importlib

    args = parse_args(sys.argv[1:])
    modules = SMOKE_MODULES if args.smoke and not args.filters else MODULES
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    print("name,us_per_call,derived")
    t_all = time.time()
    all_rows = []
    for modname in modules:
        if args.filters and not any(f in modname for f in args.filters):
            continue
        t0 = time.time()
        mod = importlib.import_module(modname)
        for row in mod.run():
            all_rows.append(row)
            print(row.csv(), flush=True)
        print(f"# {modname} done in {time.time()-t0:.1f}s", flush=True)
    print(f"# total {time.time()-t_all:.1f}s")
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump([r.to_json() for r in all_rows], f, indent=1)
        print(f"# wrote {len(all_rows)} rows to {args.json_path}")
    if args.trajectory_path:
        n = append_trajectory(args.trajectory_path, all_rows, smoke=args.smoke)
        print(f"# appended trajectory entry {n} to {args.trajectory_path}")


if __name__ == "__main__":
    main()
