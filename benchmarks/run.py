"""Benchmark driver — one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--smoke] [--json PATH]
                                               [--trajectory[=PATH] [PATH]]
                                               [module-substring ...]
Prints ``name,us_per_call,derived`` CSV rows.

``--smoke`` runs a fast subset (and tells modules that honour
``REPRO_BENCH_SMOKE`` to shrink their collections) — used by
``scripts/check.sh`` as a does-the-benchmark-stack-still-run gate.

``--json PATH`` additionally writes every row (including any attached
``JoinStats`` dict — counters, filter_ratio, precision, overflow_blocks) to
PATH as a JSON list, so perf/filter-ratio trajectories can be diffed across
PRs instead of eyeballing CSV.

``--trajectory [PATH]`` *appends* one summary entry (timestamp, git
revision, row list with stats) to the JSON list at PATH — the cross-PR perf
trajectory.  The output path is a parameter (``--trajectory=PATH`` or a
following non-flag argument); bare ``--trajectory`` defaults to the
repo-root ``BENCH_PR5.json``.  ``scripts/check.sh`` passes the path
explicitly (overridable via ``REPRO_BENCH_TRAJECTORY``), so every gate run
extends the history instead of overwriting it.  When using the bare form
together with module filters, put the filters first — the token right
after ``--trajectory`` is taken as the path unless it starts with ``-``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_TRAJECTORY = os.path.join(_REPO_ROOT, "BENCH_PR5.json")

MODULES = [
    "benchmarks.bench_expected_bounds",    # Fig. 5 / Eq. 4-6
    "benchmarks.bench_cutoffs",            # Fig. 6-7 / Alg. 6
    "benchmarks.bench_cpu_algos",          # Tables 5-8
    "benchmarks.bench_filter_ratio",       # Table 9
    "benchmarks.bench_generation_methods", # Fig. 10
    "benchmarks.bench_precision",          # Fig. 11
    "benchmarks.bench_device_join",        # Table 10
    "benchmarks.bench_rs_join",            # R×S vs self-join
    "benchmarks.bench_engine",             # prepared-vs-rebuild amortization
    "benchmarks.bench_kernels",            # kernel roofline (DESIGN §6)
]

SMOKE_MODULES = [
    "benchmarks.bench_expected_bounds",
    "benchmarks.bench_rs_join",
    "benchmarks.bench_engine",
]


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def append_trajectory(path: str, rows, *, smoke: bool) -> int:
    """Append one run summary to the JSON trajectory list at ``path``.

    The file holds a list of entries ``{ts, rev, smoke, rows}``; a corrupt or
    non-list file is replaced rather than crashing the gate (the trajectory
    is observability, not a correctness artifact).  Returns the new length.
    """
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                loaded = json.load(f)
            if isinstance(loaded, list):
                history = loaded
        except (json.JSONDecodeError, OSError):
            history = []
    history.append({
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "rev": _git_rev(),
        "smoke": smoke,
        "rows": [r.to_json() for r in rows],
    })
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=1)
    os.replace(tmp, path)
    return len(history)


def main() -> None:
    import importlib

    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            raise SystemExit("--json needs a path argument")
        json_path = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    trajectory_path = None
    for a in argv:
        if a.startswith("--trajectory="):
            trajectory_path = a.split("=", 1)[1] or DEFAULT_TRAJECTORY
            argv = [x for x in argv if x != a]
            break
    if "--trajectory" in argv:
        i = argv.index("--trajectory")
        if i + 1 < len(argv) and not argv[i + 1].startswith("-"):
            trajectory_path = argv[i + 1]
            argv = argv[:i] + argv[i + 2:]
        else:
            trajectory_path = DEFAULT_TRAJECTORY
            argv = argv[:i] + argv[i + 1:]
    filters = [a for a in argv if not a.startswith("-")]
    modules = SMOKE_MODULES if smoke and not filters else MODULES
    if smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    print("name,us_per_call,derived")
    t_all = time.time()
    all_rows = []
    for modname in modules:
        if filters and not any(f in modname for f in filters):
            continue
        t0 = time.time()
        mod = importlib.import_module(modname)
        for row in mod.run():
            all_rows.append(row)
            print(row.csv(), flush=True)
        print(f"# {modname} done in {time.time()-t0:.1f}s", flush=True)
    print(f"# total {time.time()-t_all:.1f}s")
    if json_path:
        with open(json_path, "w") as f:
            json.dump([r.to_json() for r in all_rows], f, indent=1)
        print(f"# wrote {len(all_rows)} rows to {json_path}")
    if trajectory_path:
        n = append_trajectory(trajectory_path, all_rows, smoke=smoke)
        print(f"# appended trajectory entry {n} to {trajectory_path}")


if __name__ == "__main__":
    main()
