"""Paper Table 10 — the device-side all-pairs bitmap join (Algorithm 8,
TPU-adapted) vs the best CPU algorithm.

On this container the 'device' is the XLA-compiled blocked join (ref kernel
path — the Pallas kernels target TPU and are validated in interpret mode);
the paper's GPU/CPU speedup structure (device join wins at low tau / dense
collections) is what we reproduce.  Sweeps bitmap sizes like the paper."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, collection
from repro.core import cpu_algos, join
from repro.core.filters import BitmapFilter

TAUS = (0.5, 0.6, 0.7, 0.75)


def _best_cpu(col, tau) -> tuple:
    best = (None, float("inf"))
    bf = BitmapFilter.build(col.tokens, col.lengths, "jaccard", tau, b=64)
    for name in ("allpairs", "ppjoin", "groupjoin", "adaptjoin"):
        t0 = time.perf_counter()
        cpu_algos.ALGORITHMS[name](col, "jaccard", tau, bitmap=bf)
        dt = time.perf_counter() - t0
        if dt < best[1]:
            best = (name, dt)
    return best


def run() -> List[Row]:
    rows: List[Row] = []
    for cname, n in (("uniform", 2000), ("dblp", 700)):
        col = collection(cname, n)
        for tau in TAUS:
            cpu_name, cpu_t = _best_cpu(col, tau)
            best_dev = (None, float("inf"), 0)
            for b in (64, 128, 256):
                # warm (compile) then measure
                join.blocked_bitmap_join(col, "jaccard", tau, b=b, block=2048)
                t0 = time.perf_counter()
                pairs = join.blocked_bitmap_join(col, "jaccard", tau, b=b,
                                                 block=2048)
                dt = time.perf_counter() - t0
                if dt < best_dev[1]:
                    best_dev = (b, dt, len(pairs))
            b, dev_t, npairs = best_dev
            rows.append(Row(
                f"table10_device_join_{cname}_tau{tau}", dev_t * 1e6,
                f"speedup={cpu_t/dev_t:.2f}x vs best-CPU({cpu_name}={cpu_t*1e6:.0f}us) "
                f"best_b={b} pairs={npairs}"))
            # Same join through the device-resident compaction path (the
            # dense bool tile never crosses to the host).
            join.blocked_bitmap_join(col, "jaccard", tau, b=b, block=2048,
                                     compaction="device")
            t0 = time.perf_counter()
            rpairs, rstats = join.blocked_bitmap_join(
                col, "jaccard", tau, b=b, block=2048, compaction="device",
                return_stats=True)
            res_t = time.perf_counter() - t0
            assert len(rpairs) == npairs
            rows.append(Row(
                f"table10_resident_join_{cname}_tau{tau}", res_t * 1e6,
                f"host_compaction={dev_t*1e6:.0f}us b={b} pairs={npairs}",
                stats=rstats.to_dict()))
    return rows
