"""The request coalescer: an explicit queue that merges incoming probe
requests into batches under a max-batch / max-wait policy.

Requests are :class:`~repro.core.collection.Collection` batches of any size
(often single sets in an online workload).  ``submit`` enqueues and returns
a :class:`ProbeTicket`; ``drain`` groups the queue FIFO into merged batches
of at most ``max_batch`` total rows.  The session executes each group as
one padded device batch and scatters per-request pair lists and
``JoinStats`` back onto the tickets — bit-identical to issuing each request
alone through ``JoinEngine.probe`` (the contract
``tests/test_serve.py::test_coalescing_exactness_*`` sweeps).

Policy knobs:

* ``max_batch`` — a group never exceeds this many probe rows (and the
  session clamps it to the plan's chunk size so a solo probe of any
  coalescable request is a single chunk — what makes per-request stats
  reconstructable).  A single request *larger* than ``max_batch`` becomes
  its own group and is routed to the sequential path.
* ``max_wait`` — ``due(now)`` turns true once the oldest queued ticket has
  waited this long, or the queue already holds a full batch;
  ``JoinSession.poll`` flushes on it.  Waiting trades a little latency for
  fuller buckets; ``max_wait=0`` degenerates to flush-per-submit.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.core.collection import Collection


@dataclasses.dataclass
class ProbeTicket:
    """One submitted probe request and, after its flush, the result."""

    request: Collection
    seq: int
    submitted_at: float
    pairs: Optional[np.ndarray] = None   # int64[K, 2] (corpus, request-local)
    stats: Optional[object] = None       # JoinStats, solo-probe-identical
    done: bool = False
    completed_at: Optional[float] = None
    route: str = ""                      # "coalesced" | "sequential"

    @property
    def rows(self) -> int:
        return self.request.num_sets

    @property
    def latency_s(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at

    def result(self):
        if not self.done:
            raise RuntimeError("probe not flushed yet; call session.flush()")
        return self.pairs, self.stats


class RequestCoalescer:
    """FIFO queue + grouping policy (no device work happens here)."""

    def __init__(self, max_batch: int = 512, max_wait: float = 0.002):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)
        self._queue: List[ProbeTicket] = []
        self._seq = 0
        self.submitted = 0
        self.drained_groups = 0

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def pending_rows(self) -> int:
        return sum(t.rows for t in self._queue)

    def submit(self, request: Collection, *,
               now: Optional[float] = None) -> ProbeTicket:
        ticket = ProbeTicket(request=request, seq=self._seq,
                             submitted_at=time.perf_counter()
                             if now is None else now)
        self._seq += 1
        self.submitted += 1
        self._queue.append(ticket)
        return ticket

    def due(self, now: Optional[float] = None) -> bool:
        """Whether the queue should flush: a full batch is waiting, or the
        oldest ticket has exceeded ``max_wait``."""
        if not self._queue:
            return False
        if self.pending_rows >= self.max_batch:
            return True
        now = time.perf_counter() if now is None else now
        return (now - self._queue[0].submitted_at) >= self.max_wait

    def drain(self) -> List[List[ProbeTicket]]:
        """Group the whole queue FIFO into merged batches.

        Greedy first-fit in arrival order: a group closes when the next
        request would push it past ``max_batch`` rows.  Oversized requests
        form singleton groups (the session routes them sequentially).
        Ordering is preserved — request k never completes after request
        k+1's group within one flush.
        """
        groups: List[List[ProbeTicket]] = []
        current: List[ProbeTicket] = []
        rows = 0
        for t in self._queue:
            if current and rows + t.rows > self.max_batch:
                groups.append(current)
                current, rows = [], 0
            current.append(t)
            rows += t.rows
            if rows >= self.max_batch:
                groups.append(current)
                current, rows = [], 0
        if current:
            groups.append(current)
        self._queue = []
        self.drained_groups += len(groups)
        return groups
