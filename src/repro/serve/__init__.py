"""Online serving layer: resident sessions, coalesced probe batching, and
double-buffered transfer pipelining over the exact-join engine."""

from repro.serve.coalescer import ProbeTicket, RequestCoalescer
from repro.serve.entrypoints import EntrypointCache, pow2_bucket
from repro.serve.session import JoinSession
from repro.serve.transfer import TransferPool

__all__ = [
    "EntrypointCache",
    "JoinSession",
    "ProbeTicket",
    "RequestCoalescer",
    "TransferPool",
    "pow2_bucket",
]
