"""The resident join session: one prepared corpus held on device for the
session's lifetime, probed by coalesced, padded, pipelined request batches.

``JoinEngine.probe`` already amortizes the *build* (prepare R once); this
layer amortizes the *serve*: every per-probe cost that is constant work —
host round-trips, fresh traces for new batch sizes, serialized
upload→join→download — is hoisted out of the request path:

* **Resident session** — construction eagerly uploads every corpus-side
  artifact (tokens/lengths, packed bitmap words, the postings CSR, the
  min-overlap table) so no probe ever rebuilds or re-uploads them; the
  ``PreparedCollection`` build counters prove it.
* **Bucketed entrypoints** — merged batches are padded to power-of-two
  buckets (rows, token width, prefix width, candidate capacity), so each
  bucket traces exactly once (:class:`repro.serve.entrypoints.
  EntrypointCache`; ``stats_summary()['entrypoints']['traces']`` is the
  steady-state-no-retrace proof).
* **Request coalescing** — the :class:`~repro.serve.coalescer.
  RequestCoalescer` merges queued requests into one padded device batch
  per group; per-request pair lists and ``JoinStats`` are scattered back
  out **bit-identical to probing each request alone** (per-probe-row
  funnel counters are segment-summed on device, so even the stats match
  the solo run exactly — swept by ``tests/test_serve.py``).
* **Double-buffered transfers** — batch N+1 is staged and ``device_put``
  through the :class:`~repro.serve.transfer.TransferPool` and its step
  dispatched *before* batch N's outputs are fetched, so upload overlaps
  the in-flight join under JAX async dispatch (``pipeline_depth``).
* **Live corpus** — ``append()`` seals new documents as
  :mod:`repro.store` delta segments between batches: the warm entrypoints
  keep serving the untouched base (zero new traces on append), delta
  results are merged in bit-identically, and only compaction — which
  swaps the base — rebinds the resident arrays and retraces.

Exactness routing: the coalesced fast path serves a request iff its solo
probe would run it as a single non-overflowing fused chunk — the session
computes the same host count-prepass the driver would and routes anything
else (oversized requests, forced-capacity overflows, pathological
expansions, non-indexed plans) through ``JoinEngine.probe`` itself.  The
fast path is therefore an optimization of a path that always exists, never
a second semantics.
"""

from __future__ import annotations

import collections
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import bitmap as bm
from repro.core import bounds, expected, verify
from repro.core.collection import Collection
from repro.core.constants import BITMAP_COMBINED, JACCARD, PAD_TOKEN
from repro.core.engine import JoinEngine, PreparedCollection, prepare
from repro.core.join import JoinStats
from repro.core.plan import JoinPlan, JoinPlanner
from repro.serve.coalescer import ProbeTicket, RequestCoalescer
from repro.serve.entrypoints import EntrypointCache, pow2_bucket
from repro.serve.transfer import TransferPool


def _probe_step_impl(tokens_r, lengths_r, words_r,
                     vocab, vocab_tid, post_set, post_pos, post_len, post_key,
                     probe_tokens, probe_lengths, probe_prefix, lo_r, hi_r,
                     need_tab,
                     *, sim: str, tau: float, b: int, method: str, mix: bool,
                     cap: int, lp: int, scale: int, cutoff: int, impl: str):
    """One fused serving step over a coalesced probe batch.

    The same three traced stages as the indexed driver's chunk step
    (:func:`repro.index.candidates._indexed_chunk_step`), with two serving
    additions: probe bitmap words are generated *inside* the step (one
    fusion, no separate upload), and the bitmap-survivor / verified masks
    are segment-summed per probe row so per-request funnel counters can be
    recovered from the merged batch exactly.
    """
    import jax.numpy as jnp

    from repro.index.candidates import (dedup_pairs, expand_and_filter,
                                        verdict_and_verify)

    probe_words = bm.generate_bitmaps(probe_tokens, probe_lengths, b,
                                      method=method, mix=mix)
    rr, ss, _n_exp = expand_and_filter(
        post_set, post_pos, post_len, post_key, vocab, vocab_tid,
        probe_tokens, probe_lengths, probe_prefix, lo_r, hi_r, jnp.int32(0),
        sim=sim, tau=tau, cap=cap, lp=lp, scale=scale, self_join=False,
        impl=impl)
    cand_r, cand_s, n_gen = dedup_pairs(rr, ss, cap)
    slot_ok = jnp.arange(cap) < n_gen
    pairs, _n_bm, n_ok, bm_mask, ok_mask = verdict_and_verify(
        tokens_r, lengths_r, words_r, probe_tokens, probe_lengths,
        probe_words, cand_r, cand_s, slot_ok, need_tab, jnp.int32(0),
        sim=sim, tau=tau, cutoff=cutoff, impl=impl, return_masks=True)
    cb = probe_tokens.shape[0]
    safe_s = jnp.where(slot_ok, cand_s, 0)
    gen_rows = jnp.zeros((cb,), jnp.int32).at[safe_s].add(
        slot_ok.astype(jnp.int32))
    bm_rows = jnp.zeros((cb,), jnp.int32).at[safe_s].add(
        bm_mask.astype(jnp.int32))
    ok_rows = jnp.zeros((cb,), jnp.int32).at[safe_s].add(
        ok_mask.astype(jnp.int32))
    return pairs, n_ok, gen_rows, bm_rows, ok_rows


class _FastRequest:
    """A coalesced-path request inside one merged batch."""

    __slots__ = ("ticket", "offset", "rows", "n_exp", "lp")

    def __init__(self, ticket, offset, rows, n_exp, lp):
        self.ticket = ticket
        self.offset = offset
        self.rows = rows
        self.n_exp = n_exp
        self.lp = lp


class JoinSession:
    """A long-lived serving session over one prepared corpus.

    ``probe(batch)`` is the drop-in, single-request path (submit + flush);
    an online service uses ``submit`` per arrival plus ``poll``/``flush``,
    letting the coalescer fill padded buckets under its max-batch/max-wait
    policy.  ``stats_summary()`` is the observability surface: the engine's
    lifetime funnel rollup plus entrypoint-cache, transfer-pool,
    min-overlap-cache and coalescing counters.
    """

    def __init__(self, corpus, sim: str = JACCARD, tau: float = 0.8, *,
                 plan: Optional[JoinPlan] = None,
                 planner: Optional[JoinPlanner] = None,
                 max_batch: int = 512,
                 max_wait: float = 0.002,
                 pipeline_depth: int = 2,
                 history_limit: Optional[int] = None,
                 policy=None,
                 device=None):
        planner = planner or JoinPlanner()
        from repro.core.engine import _as_store
        self.store = _as_store(corpus)
        if self.store is not None:
            # The store pinned one plan for every segment join at its
            # construction; the session must serve under the same plan or
            # the exactness contract (session ≡ store ≡ rebuild) breaks.
            if plan is not None and plan != self.store.plan:
                raise ValueError("session plan conflicts with the store's")
            plan = self.store.plan
            sim, tau = self.store.sim, self.store.tau
            self._prepared = self.store.base.prepared
            self.engine = JoinEngine(self.store, history_limit=history_limit)
        else:
            self._prepared = prepare(corpus)
            if plan is None:
                plan = planner.serving_plan(
                    sim, tau, n_r=max(self._prepared.num_sets, 1))
            self.engine = JoinEngine(self._prepared, sim, tau, plan=plan,
                                     planner=planner,
                                     history_limit=history_limit)
        self.plan = plan
        self.sim = sim
        self.tau = float(tau)
        self._policy = policy
        # Solo-probe parity requires any coalescable request to be a single
        # driver chunk, so the merge ceiling never exceeds the chunk size.
        self.coalescer = RequestCoalescer(
            max_batch=min(int(max_batch), int(plan.block)),
            max_wait=max_wait)
        if pipeline_depth < 1:
            raise ValueError(f"pipeline_depth must be >= 1, got "
                             f"{pipeline_depth}")
        self.pipeline_depth = int(pipeline_depth)
        self.entrypoints = EntrypointCache()
        # depth+1 staging slots: the slot staged for batch N+pipeline_depth
        # is never one an in-flight batch is still consuming.
        self.transfer = TransferPool(depth=self.pipeline_depth + 1,
                                     device=device)
        self._cap_hints: Dict[Tuple[int, int, int], int] = {}
        self.requests = 0
        self.coalesced_requests = 0
        self.sequential_requests = 0
        self.coalesced_batches = 0
        self.flushes = 0
        self.padded_rows = 0
        self.real_rows = 0
        self._bind_corpus()

    @property
    def prepared(self) -> PreparedCollection:
        """The resident corpus-side artifact: the store's live base segment
        in store mode (never stale across compactions), else the prepared
        corpus the session was built on."""
        if self.store is not None:
            return self.store.base.prepared
        return self._prepared

    def _bind_corpus(self) -> None:
        """(Re)build the resident fast path from the current base segment:
        everything corpus-side goes on device now.  Called at construction
        and again only when compaction swaps the base — appends never
        re-enter here (the no-retrace contract)."""
        plan, prepared = self.plan, self.prepared
        self._chosen = (bm.choose_method(self.tau, plan.b)
                        if plan.method == BITMAP_COMBINED else plan.method)
        self._cutoff = (expected.cutoff_point(self._chosen, plan.b, self.tau)
                        if plan.use_cutoff else 1 << 30)
        self._fast = plan.driver == "indexed" and prepared.num_sets > 0
        if self._fast:
            self._post = prepared.postings(self.sim, self.tau, plan.ell)
            if self._post.num_postings == 0:
                self._fast = False
        if self._fast:
            self._csr = self._post.device_arrays()
            self._scale = self._post.max_len + 1
            self._tokens_r, self._lengths_r = prepared.device_arrays()
            self._words_r = prepared.bitmap_words(plan.b, self._chosen,
                                                  mix=plan.mix)
            self._max_auto = self._default_max_auto()

    @staticmethod
    def _default_max_auto() -> int:
        from repro.index.candidates import _MAX_AUTO_CAPACITY
        return _MAX_AUTO_CAPACITY

    # -- live corpus ---------------------------------------------------------

    def _ensure_store(self):
        """Upgrade a frozen-corpus session to an appendable one in place:
        the current prepared corpus becomes the store's sealed base (no
        rebuild, no re-upload, no retrace) and the engine carries its
        history over via ``attach_store``."""
        if self.store is None:
            from repro.store import CorpusStore
            store = CorpusStore(self._prepared, self.sim, self.tau,
                                plan=self.plan, policy=self._policy)
            self.engine.attach_store(store)
            self.store = store
        return self.store

    def append(self, col: Collection, *, compact: bool | str = "auto"):
        """Absorb new documents between batches: seal ``col`` as a store
        delta (preparing only the delta).  Subsequent probes serve base ∪
        deltas — the warm entrypoints keep serving the untouched base, so
        appends never retrace.  If the compaction policy fires (or
        ``compact=True``), the deltas fold into a new base and the resident
        fast path rebinds to it.  Returns the new segment."""
        store = self._ensure_store()
        version = store.base_version
        seg = store.append(col, compact=compact)
        if store.base_version != version:
            self._bind_corpus()
        return seg

    def compact(self) -> bool:
        """Explicitly fold the session's deltas into a new sealed base and
        rebind the resident fast path to it.  Returns whether a merge
        happened (False on a frozen or delta-free session)."""
        if self.store is None or not self.store.compact():
            return False
        self._bind_corpus()
        return True

    # -- public API ----------------------------------------------------------

    def submit(self, request: Collection, *,
               now: Optional[float] = None) -> ProbeTicket:
        """Queue one probe request; returns its ticket (resolved by the next
        flush)."""
        self.requests += 1
        return self.coalescer.submit(request, now=now)

    def poll(self, now: Optional[float] = None) -> List[ProbeTicket]:
        """Flush iff the coalescer's max-batch/max-wait policy says so."""
        if self.coalescer.due(now):
            return self.flush()
        return []

    def probe(self, batch: Collection, *,
              return_stats: bool = True):
        """Single-request convenience with ``JoinEngine.probe`` semantics
        (and bit-identical results)."""
        ticket = self.submit(batch)
        self.flush()
        pairs, stats = ticket.result()
        return (pairs, stats) if return_stats else pairs

    def flush(self) -> List[ProbeTicket]:
        """Drain the queue: coalesce, dispatch pipelined device batches,
        scatter per-request results onto the tickets."""
        groups = self.coalescer.drain()
        if not groups:
            return []
        self.flushes += 1
        done: List[ProbeTicket] = []
        inflight: collections.deque = collections.deque()
        for group in groups:
            fast, sequential = self._route(group)
            for ticket in sequential:
                self._probe_sequential(ticket)
                done.append(ticket)
            if fast:
                # Upload + dispatch now; block on the *oldest* in-flight
                # batch only once the pipeline is full — upload of batch
                # N+1 overlaps the join of batch N.
                inflight.append(self._dispatch(fast))
                self.coalesced_batches += 1
                if len(inflight) > self.pipeline_depth:
                    done.extend(self._complete(inflight.popleft()))
        while inflight:
            done.extend(self._complete(inflight.popleft()))
        return done

    def warm_buckets(self, sample: Sequence[Collection]) -> int:
        """Pre-compile the coalesced entrypoint ladder before taking traffic.

        A lazy session compiles each (rows, width, lp, cap) bucket on first
        encounter — on a CPU backend a single XLA compile is ~1000x a flush,
        so a cold bucket hit mid-traffic stalls every queued request behind
        it.  Serving systems pre-warm instead: given representative
        ``sample`` requests, this flushes one synthetic group per
        power-of-two row bucket up to ``max_batch`` — each rung calibrates
        its own capacity hint and compiles its entrypoint, so every
        steady-state group lands on an already-traced entrypoint.  Results
        are discarded; engine/session counters do advance (warmup is real
        traffic).

        Returns the number of entrypoints compiled.  Steady-state traffic
        only compiles again if it exceeds the calibration — wider sets,
        longer prefixes, or per-group expansions beyond the calibrated cap.
        """
        if not self._fast or not sample:
            return 0
        before = self.entrypoints.stats()["traces"]
        mb = self.coalescer.max_batch

        def flush_rows(target: int) -> None:
            rows = 0
            i = 0
            while rows < target:
                req = sample[i % len(sample)]
                if req.num_sets == 0 or rows + req.num_sets > target:
                    i += 1
                    if i > 4 * len(sample):  # samples can't tile the target
                        break
                    continue
                self.submit(req)
                rows += req.num_sets
                i += 1
            self.flush()

        # Calibrate the cap hint on a full batch first, so the ladder below
        # compiles every row bucket at the final (largest) capacity.
        flush_rows(mb)
        rung = 16  # the dispatch row-bucket floor
        while rung <= pow2_bucket(mb, floor=16):
            flush_rows(min(rung, mb))
            rung *= 2
        return self.entrypoints.stats()["traces"] - before

    def stats_summary(self) -> Dict[str, object]:
        """The session's observability rollup (engine funnel totals +
        serving-layer counters)."""
        real = max(self.real_rows, 1)
        return {
            "engine": self.engine.stats_summary(),
            "entrypoints": self.entrypoints.stats(),
            "transfer": self.transfer.stats(),
            "min_overlap_cache": verify.min_overlap_cache_stats(),
            "requests": self.requests,
            "coalesced_requests": self.coalesced_requests,
            "sequential_requests": self.sequential_requests,
            "coalesced_batches": self.coalesced_batches,
            "flushes": self.flushes,
            "pad_overhead": self.padded_rows / real,
            "builds": self.prepared.build_counts(),
            "store": (self.store.stats().to_dict()
                      if self.store is not None else None),
        }

    # -- routing -------------------------------------------------------------

    def _route(self, group: Sequence[ProbeTicket]
               ) -> Tuple[List[_FastRequest], List[ProbeTicket]]:
        """Split one coalescer group into fast-path requests (with their
        solo-identical prepass counts) and sequential fallbacks."""
        if not self._fast:
            return [], list(group)
        fast: List[_FastRequest] = []
        sequential: List[ProbeTicket] = []
        offset = 0
        forced = self.plan.capacity
        for ticket in group:
            rows = ticket.rows
            if rows == 0 or rows > self.coalescer.max_batch:
                sequential.append(ticket)
                continue
            n_exp, lp = self._prepass(ticket.request)
            if n_exp > self._max_auto or (forced is not None
                                          and n_exp > int(forced)):
                # A solo probe would escalate this chunk (forced-capacity
                # overflow or pathological expansion) — run it through the
                # engine so the dense-fallback stats stay bit-identical.
                sequential.append(ticket)
                continue
            fast.append(_FastRequest(ticket, offset, rows, n_exp, lp))
            offset += rows
        return fast, sequential

    def _prepass(self, request: Collection) -> Tuple[int, int]:
        """The driver's own host count-prepass, per request: exact total
        postings expansion + this request's max prefix length."""
        from repro.index.postings import lookup_counts_host

        lengths = request.lengths
        ps = np.zeros(request.num_sets, dtype=np.int32)
        nz = lengths > 0
        if nz.any():
            ps[nz] = bounds.prefix_length(
                self.sim, self.tau, lengths[nz].astype(np.int64)
            ).astype(np.int32)
        lp = int(ps.max(initial=0))
        if lp == 0:
            return 0, 0
        lo, hi = bounds.length_window_int(self.sim, self.tau, lengths)
        cnt, _tid, valid = lookup_counts_host(
            self._post, request.tokens, ps, lo, hi, lp)
        return int(cnt[valid].sum()), lp

    # -- the coalesced fast path ---------------------------------------------

    def _dispatch(self, fast: List[_FastRequest]) -> dict:
        rows_total = sum(f.rows for f in fast)
        cb = pow2_bucket(rows_total, floor=16)
        width = pow2_bucket(max(f.ticket.request.max_len for f in fast),
                            floor=8)
        lp = pow2_bucket(max(f.lp for f in fast), floor=1)
        width = max(width, lp)
        n_exp_total = sum(f.n_exp for f in fast)
        cap = pow2_bucket(max(n_exp_total, 1), floor=128)
        # Monotone capacity hint per shape bucket: group compositions vary
        # run to run, and letting every n_exp total pick its own pow2 cap
        # would keep minting fresh entrypoints (and traces) near bucket
        # boundaries.  Reusing the largest cap seen for this row bucket
        # keeps one steady-state entrypoint per bucket without oversizing
        # small groups (cap slots beyond n_generated are padding, but the
        # dedup sort still pays for them) — ``warm_buckets`` calibrates one
        # representative cap per rung before traffic.
        hint_key = (cb, width, lp)
        cap = max(cap, self._cap_hints.get(hint_key, 0))
        self._cap_hints[hint_key] = cap

        tokens = np.full((cb, width), PAD_TOKEN, dtype=np.int32)
        lengths = np.zeros((cb,), dtype=np.int32)
        prefix = np.zeros((cb,), dtype=np.int32)
        lo = np.zeros((cb,), dtype=np.int32)
        hi = np.zeros((cb,), dtype=np.int32)
        for f in fast:
            req = f.ticket.request
            o, n = f.offset, f.rows
            tokens[o:o + n, :req.max_len] = req.tokens
            lengths[o:o + n] = req.lengths
            nz = req.lengths > 0
            if nz.any():
                prefix[o:o + n][nz] = bounds.prefix_length(
                    self.sim, self.tau, req.lengths[nz].astype(np.int64)
                ).astype(np.int32)
            rlo, rhi = bounds.length_window_int(self.sim, self.tau,
                                                req.lengths)
            lo[o:o + n] = rlo
            hi[o:o + n] = rhi
        self.real_rows += rows_total
        self.padded_rows += cb - rows_total

        dev = self.transfer.upload((cb, width), [tokens, lengths, prefix,
                                                 lo, hi])
        need_tab = verify.min_overlap_table_dev(
            self.sim, self.tau, self.prepared.max_len, int(width))
        step = self._entrypoint(cb, width, lp, cap)
        outputs = step(self._tokens_r, self._lengths_r, self._words_r,
                       *self._csr, *dev, need_tab)
        return {"fast": fast, "outputs": outputs}

    def _entrypoint(self, cb: int, width: int, lp: int, cap: int):
        import jax

        key = ("serve_probe", self.plan.driver, self.sim, self.tau,
               cb, width, lp, cap)
        statics = dict(sim=self.sim, tau=self.tau, b=self.plan.b,
                       method=self._chosen, mix=self.plan.mix, cap=cap,
                       lp=lp, scale=self._scale, cutoff=int(self._cutoff),
                       impl=self.plan.impl)
        cache = self.entrypoints

        def build():
            def fn(*args):
                cache.note_trace(key)   # trace-time only: the retrace proof
                return _probe_step_impl(*args, **statics)
            return jax.jit(fn)

        return cache.get(key, build)

    def _complete(self, ctx: dict) -> List[ProbeTicket]:
        pairs_d, n_ok, gen_rows, bm_rows, ok_rows = ctx["outputs"]
        k = int(n_ok)                       # blocks on the step's results
        pairs = np.asarray(pairs_d)[:k]
        gen_rows = np.asarray(gen_rows)
        bm_rows = np.asarray(bm_rows)
        ok_rows = np.asarray(ok_rows)
        gi = (self.prepared.order[pairs[:, 0]] if k
              else np.zeros((0,), dtype=np.int64))
        s = pairs[:, 1] if k else np.zeros((0,), dtype=np.int64)
        now = time.perf_counter()
        done = []
        live = self.store is not None and bool(self.store.deltas)
        for f in ctx["fast"]:
            o, n = f.offset, f.rows
            m = (s >= o) & (s < o + n)
            sub = np.stack([gi[m], s[m] - o], axis=1).astype(np.int64)
            sub = sub[np.lexsort((sub[:, 1], sub[:, 0]))]
            if f.lp == 0:
                # A solo probe short-circuits before its chunk loop when no
                # row has a prefix — all-zero stats, not a "skipped block".
                stats = JoinStats()
            else:
                g = int(gen_rows[o:o + n].sum())
                stats = JoinStats(
                    total_pairs=g,
                    blocks_total=1,
                    blocks_skipped=int(f.n_exp == 0),
                    candidates=int(bm_rows[o:o + n].sum()),
                    verified_true=int(ok_rows[o:o + n].sum()),
                    candidates_generated=g,
                    postings_expanded=f.n_exp)
            if live:
                # The device step served the sealed base; the delta part is
                # the *same* per-delta engine probes the sequential path
                # runs, so merged pairs + summed stats stay bit-identical
                # to ``store.probe`` (base pairs are store-global already —
                # the base sits at offset 0).
                from repro.store.store import merge_pairs, sum_stats
                dpairs, dstats = self.store.probe_deltas(f.ticket.request)
                if len(dpairs):
                    sub = merge_pairs([sub, dpairs])
                if dstats:
                    stats = sum_stats([stats] + dstats)
            t = f.ticket
            t.pairs, t.stats = sub, stats
            t.done, t.completed_at, t.route = True, now, "coalesced"
            self.engine.record_probe(stats)
            self.coalesced_requests += 1
            done.append(t)
        return done

    # -- the sequential fallback ---------------------------------------------

    def _probe_sequential(self, ticket: ProbeTicket) -> None:
        pairs, stats = self.engine.probe(ticket.request)
        ticket.pairs, ticket.stats = pairs, stats
        ticket.done = True
        ticket.completed_at = time.perf_counter()
        ticket.route = "sequential"
        self.sequential_requests += 1
