"""Double-buffered host→device transfer pools for the serving layer.

Per-probe ``jnp.asarray`` calls allocate a fresh host staging buffer and a
fresh device buffer for every batch; a long-lived session uploading the same
padded bucket shapes thousands of times can instead reuse a small ring of
staging buffers per bucket (the ``TransferBufferPool`` idea from
SHARK-Engine's serving stack).  :class:`TransferPool` keeps ``depth``
staging slots per (bucket) key:

* ``upload(key, arrays)`` copies the batch into the next slot's pooled host
  buffers (``np.copyto`` — no per-batch allocation once a bucket is warm)
  and issues one ``jax.device_put`` for the group;
* slots are rotated round-robin, so with ``depth >= pipeline_depth + 1``
  the slot being staged for batch N+1 is never one whose device copy batch
  N's still-in-flight step may be reading — the upload of batch N+1 can
  overlap the join of batch N under JAX async dispatch;
* counters (``slot_builds`` / ``uploads`` / ``staged_bytes``) make buffer
  reuse assertable: after bucket warmup ``slot_builds`` stops moving while
  ``uploads`` keeps counting.

Buffer donation (reusing the *device* allocation across uploads) is only
honoured by XLA on TPU/GPU; on those backends ``jax.jit`` donation on the
probe step covers it, so the pool keeps to host-staging reuse and leaves
device-buffer lifetime to the runtime.
"""

from __future__ import annotations

import threading
from typing import Dict, Hashable, List, Sequence

import numpy as np


class _Slot:
    __slots__ = ("host", "signature")

    def __init__(self, arrays: Sequence[np.ndarray]):
        self.host = [np.empty_like(a) for a in arrays]
        self.signature = tuple((a.shape, a.dtype.str) for a in arrays)


class TransferPool:
    """A ring of reusable host staging buffers per bucket key, uploaded to
    the device in one ``jax.device_put`` per batch."""

    def __init__(self, depth: int = 3, device=None):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.depth = int(depth)
        self.device = device
        self._lock = threading.Lock()
        self._slots: Dict[Hashable, List[_Slot]] = {}
        self._next: Dict[Hashable, int] = {}
        self.slot_builds = 0
        self.uploads = 0
        self.staged_bytes = 0

    def _acquire(self, key: Hashable, arrays: Sequence[np.ndarray]) -> _Slot:
        signature = tuple((a.shape, a.dtype.str) for a in arrays)
        with self._lock:
            ring = self._slots.setdefault(key, [])
            # A key whose shapes changed (e.g. the session widened its token
            # bucket) drops its stale ring — the signature IS the bucket.
            if ring and ring[0].signature != signature:
                ring.clear()
                self._next[key] = 0
            if len(ring) < self.depth:
                slot = _Slot(arrays)
                ring.append(slot)
                self.slot_builds += 1
                return slot
            i = self._next.get(key, 0)
            self._next[key] = (i + 1) % self.depth
            return ring[i]

    def upload(self, key: Hashable, arrays: Sequence[np.ndarray]):
        """Stage ``arrays`` into pooled host buffers and put them on device.

        Returns the device arrays (one per input).  The copy into the pooled
        staging buffer is synchronous; the device transfer is issued
        immediately and may complete asynchronously — callers pipeline by
        uploading batch N+1 before blocking on batch N's outputs.
        """
        import jax

        slot = self._acquire(key, arrays)
        for buf, a in zip(slot.host, arrays):
            np.copyto(buf, a)
        dev = jax.device_put(slot.host, self.device)
        with self._lock:
            self.uploads += 1
            self.staged_bytes += sum(b.nbytes for b in slot.host)
        return dev

    def stats(self) -> dict:
        with self._lock:
            return {"depth": self.depth,
                    "buckets": len(self._slots),
                    "slot_builds": self.slot_builds,
                    "uploads": self.uploads,
                    "staged_bytes": self.staged_bytes}
