"""The bucketed entrypoint cache: one traced executable per (entrypoint,
bucket) key, with trace counters that make "no retrace on steady state" an
assertable property instead of a hope.

The ring and sharded-indexed drivers memoize their traced factories
(``core/join._ring_sweep_fn``, ``distributed/sharded_index.
_sharded_chunk_fn``) through this cache — it is the generalization of the
``functools.lru_cache`` they used to carry, shared with the serving layer:

* a bounded, lock-guarded key → entrypoint map (``get``), where the builder
  runs at most once per key;
* a **trace counter** fed from *inside* the traced function
  (:meth:`EntrypointCache.note_trace` is a host callback the builder embeds
  in the jitted body, so it fires exactly when JAX traces — on the first
  call per shape signature, and again only if something silently retraces);
* :func:`pow2_bucket` — the padding policy that makes shape signatures
  recur: probe batches are padded up to power-of-two row counts so each
  ``(driver, sim, tau, bucket)`` traces exactly once for the life of the
  session.

``SERVE_ENTRYPOINTS`` in :mod:`repro.serve.session` asserts ``traces ==
entries`` after warmup; the check.sh serve smoke pins it.
"""

from __future__ import annotations

import collections
import threading
from typing import Callable, Dict, Hashable


def pow2_bucket(n: int, floor: int = 1) -> int:
    """Round ``n`` up to a power of two ``>= floor`` — the serving layer's
    padding policy for probe-batch rows, prefix widths and candidate
    capacities (same shape-bucketing idea as ``core.join._bucket_capacity``,
    reusable for any dimension)."""
    return max(int(floor), 1 << max(int(n) - 1, 0).bit_length())


class EntrypointCache:
    """Bounded key → traced-entrypoint cache with build and trace counters.

    ``get(key, builder)`` returns the cached entrypoint, calling ``builder``
    (zero-arg) at most once per key; eviction is LRU.  Builders that want
    retraces *proven* absent call :meth:`note_trace` inside the function
    they hand to ``jax.jit`` — the call runs at trace time only, so after
    warmup ``stats()['traces']`` must stop moving (``== entries`` when every
    key has exactly one shape signature).
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = int(maxsize)
        self._lock = threading.Lock()
        self._data: "collections.OrderedDict" = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.traces = 0
        self.trace_counts: Dict[Hashable, int] = {}

    def get(self, key: Hashable, builder: Callable[[], Callable]):
        with self._lock:
            if key in self._data:
                self.hits += 1
                self._data.move_to_end(key)
                return self._data[key]
            # Build under the lock: builders only *construct* the jitted
            # callable (tracing is deferred to the first call), so this is
            # cheap and deduplicates concurrent misses.
            self.misses += 1
            fn = builder()
            self._data[key] = fn
            while len(self._data) > self.maxsize:
                evicted, _ = self._data.popitem(last=False)
                self.trace_counts.pop(evicted, None)
            return fn

    def note_trace(self, key: Hashable) -> None:
        """Record one trace of ``key``'s entrypoint.  Call this *inside* the
        function handed to ``jax.jit`` — it executes only while JAX traces,
        never on cached-executable dispatch."""
        with self._lock:
            self.traces += 1
            self.trace_counts[key] = self.trace_counts.get(key, 0) + 1

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._data), "hits": self.hits,
                    "misses": self.misses, "traces": self.traces,
                    "max_traces_per_key": max(self.trace_counts.values(),
                                              default=0)}

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.trace_counts.clear()
            self.hits = self.misses = self.traces = 0
