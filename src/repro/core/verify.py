"""Exact verification of candidate pairs.

Device path: batched, branch-free merge-intersection over the padded sorted
token layout via ``searchsorted`` (O(L log L) per pair, fully vectorised).
Host path: numpy verification with the early-termination bound of [13]
(used by the faithful CPU algorithm reproductions, where candidate counts are
small and early exit matters).
"""

from __future__ import annotations

import collections
import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds
from repro.core.constants import PAD_TOKEN


# ---------------------------------------------------------------------------
# Device (JAX) path
# ---------------------------------------------------------------------------

def _row_overlap(tok_r: jnp.ndarray, tok_s: jnp.ndarray) -> jnp.ndarray:
    """Overlap of two sorted padded token rows (int32[L], PAD-padded)."""
    idx = jnp.searchsorted(tok_s, tok_r)
    idx = jnp.clip(idx, 0, tok_s.shape[0] - 1)
    hit = (tok_s[idx] == tok_r) & (tok_r != PAD_TOKEN)
    return jnp.sum(hit.astype(jnp.int32))


pairwise_overlap = jax.vmap(_row_overlap)


@functools.partial(jax.jit, static_argnames=())
def overlap_many(tokens: jnp.ndarray, idx_r: jnp.ndarray, idx_s: jnp.ndarray) -> jnp.ndarray:
    """Exact overlaps for candidate pairs (idx_r[i], idx_s[i]) of one collection."""
    return pairwise_overlap(tokens[idx_r], tokens[idx_s])


class _MinOverlapTableCache:
    """Bounded LRU for device-resident min-overlap tables, safe under
    concurrent probes.

    ``functools.lru_cache`` keeps its *dict* consistent under CPython
    threading, but two threads missing on the same key would both build and
    upload the table — and a long-lived serving session
    (:mod:`repro.serve`) probes from worker threads where that duplicated
    upload is exactly the cost the cache exists to avoid.  This cache
    double-checks under one lock (the table build itself happens outside
    the lock so a slow upload never serializes unrelated probes) and counts
    hits/misses, surfaced through ``JoinSession.stats_summary()``.
    """

    def __init__(self, maxsize: int = 64):
        self.maxsize = maxsize
        self._lock = threading.Lock()
        self._data: "collections.OrderedDict" = collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, sim: str, tau: float, lmax_r: int, lmax_s: int):
        key = (sim, float(tau), int(lmax_r), int(lmax_s))
        with self._lock:
            if key in self._data:
                self.hits += 1
                self._data.move_to_end(key)
                return self._data[key]
            self.misses += 1
        # Build outside the lock: a concurrent miss on the same key wastes
        # one duplicate upload at worst, but never blocks other keys.
        table = jnp.asarray(bounds.min_overlap_table(sim, tau, lmax_r, lmax_s))
        with self._lock:
            if key not in self._data:
                self._data[key] = table
                while len(self._data) > self.maxsize:
                    self._data.popitem(last=False)
            return self._data[key]

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._data), "maxsize": self.maxsize}

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.hits = 0
            self.misses = 0


_TABLE_CACHE = _MinOverlapTableCache(maxsize=64)


def min_overlap_table_dev(sim: str, tau: float, lmax_r: int, lmax_s: int):
    """Device twin of ``bounds.min_overlap_table`` — cached (bounded,
    lock-guarded LRU, see :class:`_MinOverlapTableCache`) so repeated
    verify/probe calls — one per block pair in the blocked host path, one
    per probe in the serving shape — do not re-upload the same table.
    Shared by every driver's verification site."""
    return _TABLE_CACHE.get(sim, tau, lmax_r, lmax_s)


def min_overlap_cache_stats() -> dict:
    """Hit/miss/entry counters of the min-overlap table cache (surfaced by
    ``repro.serve.JoinSession.stats_summary``)."""
    return _TABLE_CACHE.stats()


_min_overlap_table_dev = min_overlap_table_dev  # internal alias


@functools.partial(jax.jit, static_argnames=("sim",))
def _verify_pairs_jit(tokens, lengths, idx_r, idx_s, table, sim):
    o = overlap_many(tokens, idx_r, idx_s)
    need = bounds.min_overlap_gather(sim, table, lengths[idx_r], lengths[idx_s])
    return o >= need


def verify_pairs(
    tokens: jnp.ndarray,
    lengths: jnp.ndarray,
    idx_r: jnp.ndarray,
    idx_s: jnp.ndarray,
    sim: str,
    tau: float,
) -> jnp.ndarray:
    """bool[K] — whether each candidate pair is truly similar.

    Acceptance is decided by comparing the exact integer overlap against
    the host-built integer :func:`repro.core.bounds.min_overlap_table` —
    never by re-deriving the Table 1 threshold in device float32, whose
    rounding lands a few ulps off the oracle's float64 value and flips
    membership of exactly-at-threshold pairs (e.g. |r| = 28 ⊂ |s| = 35 at
    Jaccard 0.8).  Every driver therefore agrees with ``naive_join``
    bit-for-bit.
    """
    lmax = int(tokens.shape[1])
    tab = _min_overlap_table_dev(sim, float(tau), lmax, lmax)
    return _verify_pairs_jit(tokens, lengths, idx_r, idx_s, tab, sim)


@functools.partial(jax.jit, static_argnames=("sim",))
def _verify_pairs_rs_jit(tokens_r, lengths_r, tokens_s, lengths_s,
                         idx_r, idx_s, table, sim):
    o = pairwise_overlap(tokens_r[idx_r], tokens_s[idx_s])
    need = bounds.min_overlap_gather(sim, table, lengths_r[idx_r],
                                     lengths_s[idx_s])
    return o >= need


def verify_pairs_rs(
    tokens_r: jnp.ndarray,
    lengths_r: jnp.ndarray,
    tokens_s: jnp.ndarray,
    lengths_s: jnp.ndarray,
    idx_r: jnp.ndarray,
    idx_s: jnp.ndarray,
    sim: str,
    tau: float,
) -> jnp.ndarray:
    """RS-join variant of :func:`verify_pairs` (same integer-exact
    acceptance table)."""
    tab = _min_overlap_table_dev(sim, float(tau), int(tokens_r.shape[1]),
                                 int(tokens_s.shape[1]))
    return _verify_pairs_rs_jit(tokens_r, lengths_r, tokens_s, lengths_s,
                                idx_r, idx_s, tab, sim)


# ---------------------------------------------------------------------------
# Host (numpy) path — early-termination merge of [13]
# ---------------------------------------------------------------------------

def overlap_early_terminate(r: np.ndarray, s: np.ndarray, required: float) -> int:
    """Sorted-merge overlap with the early-termination condition of [13].

    Stops as soon as the remaining elements cannot reach ``required`` overlap.
    Returns the exact overlap if it is >= required, otherwise a value < required
    (possibly a partial count — callers only compare against ``required``).
    """
    i = j = o = 0
    lr, ls = len(r), len(s)
    while i < lr and j < ls:
        # Early termination: even if every remaining element matched.
        if o + min(lr - i, ls - j) < required:
            return o
        ri, sj = r[i], s[j]
        if ri == sj:
            o += 1
            i += 1
            j += 1
        elif ri < sj:
            i += 1
        else:
            j += 1
    return o


def overlap_numpy(r: np.ndarray, s: np.ndarray) -> int:
    """Vectorised exact overlap (no early termination)."""
    idx = np.searchsorted(s, r)
    idx = np.clip(idx, 0, len(s) - 1)
    return int(np.sum(s[idx] == r)) if len(s) else 0
