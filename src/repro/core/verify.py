"""Exact verification of candidate pairs.

Device path: batched, branch-free merge-intersection over the padded sorted
token layout via ``searchsorted`` (O(L log L) per pair, fully vectorised).
Host path: numpy verification with the early-termination bound of [13]
(used by the faithful CPU algorithm reproductions, where candidate counts are
small and early exit matters).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds
from repro.core.constants import PAD_TOKEN


# ---------------------------------------------------------------------------
# Device (JAX) path
# ---------------------------------------------------------------------------

def _row_overlap(tok_r: jnp.ndarray, tok_s: jnp.ndarray) -> jnp.ndarray:
    """Overlap of two sorted padded token rows (int32[L], PAD-padded)."""
    idx = jnp.searchsorted(tok_s, tok_r)
    idx = jnp.clip(idx, 0, tok_s.shape[0] - 1)
    hit = (tok_s[idx] == tok_r) & (tok_r != PAD_TOKEN)
    return jnp.sum(hit.astype(jnp.int32))


pairwise_overlap = jax.vmap(_row_overlap)


@functools.partial(jax.jit, static_argnames=())
def overlap_many(tokens: jnp.ndarray, idx_r: jnp.ndarray, idx_s: jnp.ndarray) -> jnp.ndarray:
    """Exact overlaps for candidate pairs (idx_r[i], idx_s[i]) of one collection."""
    return pairwise_overlap(tokens[idx_r], tokens[idx_s])


@functools.partial(jax.jit, static_argnames=("sim",))
def verify_pairs(
    tokens: jnp.ndarray,
    lengths: jnp.ndarray,
    idx_r: jnp.ndarray,
    idx_s: jnp.ndarray,
    sim: str,
    tau: float,
) -> jnp.ndarray:
    """bool[K] — whether each candidate pair is truly similar."""
    o = overlap_many(tokens, idx_r, idx_s)
    need = bounds.equivalent_overlap(sim, tau, lengths[idx_r], lengths[idx_s])
    return o >= need


@functools.partial(jax.jit, static_argnames=("sim",))
def verify_pairs_rs(
    tokens_r: jnp.ndarray,
    lengths_r: jnp.ndarray,
    tokens_s: jnp.ndarray,
    lengths_s: jnp.ndarray,
    idx_r: jnp.ndarray,
    idx_s: jnp.ndarray,
    sim: str,
    tau: float,
) -> jnp.ndarray:
    """RS-join variant of :func:`verify_pairs`."""
    o = pairwise_overlap(tokens_r[idx_r], tokens_s[idx_s])
    need = bounds.equivalent_overlap(sim, tau, lengths_r[idx_r], lengths_s[idx_s])
    return o >= need


# ---------------------------------------------------------------------------
# Host (numpy) path — early-termination merge of [13]
# ---------------------------------------------------------------------------

def overlap_early_terminate(r: np.ndarray, s: np.ndarray, required: float) -> int:
    """Sorted-merge overlap with the early-termination condition of [13].

    Stops as soon as the remaining elements cannot reach ``required`` overlap.
    Returns the exact overlap if it is >= required, otherwise a value < required
    (possibly a partial count — callers only compare against ``required``).
    """
    i = j = o = 0
    lr, ls = len(r), len(s)
    while i < lr and j < ls:
        # Early termination: even if every remaining element matched.
        if o + min(lr - i, ls - j) < required:
            return o
        ri, sj = r[i], s[j]
        if ri == sj:
            o += 1
            i += 1
            j += 1
        elif ri < sj:
            i += 1
        else:
            j += 1
    return o


def overlap_numpy(r: np.ndarray, s: np.ndarray) -> int:
    """Vectorised exact overlap (no early termination)."""
    idx = np.searchsorted(s, r)
    idx = np.clip(idx, 0, len(s) - 1)
    return int(np.sum(s[idx] == r)) if len(s) else 0
