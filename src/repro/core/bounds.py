"""Similarity functions, threshold conversions and the Eq. 2 upper bound.

Implements Table 1 (similarity functions + equivalent overlap), Table 2
(length bounds + prefix lengths) and Theorem 1 / Eq. 2 (the bitmap overlap
upper bound).  Everything is dtype-polymorphic: works on numpy arrays, python
scalars and jnp arrays (all ops are elementwise).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.constants import COSINE, DICE, JACCARD, OVERLAP


# ---------------------------------------------------------------------------
# Similarity functions (Table 1)
# ---------------------------------------------------------------------------

def similarity(sim: str, overlap, len_r, len_s):
    """sim(r, s) given |r ∩ s| and the set sizes."""
    o = overlap
    if sim == OVERLAP:
        return o
    if sim == JACCARD:
        return o / (len_r + len_s - o)
    if sim == COSINE:
        return o / (len_r * 1.0 * len_s) ** 0.5
    if sim == DICE:
        return 2.0 * o / (len_r + len_s)
    raise ValueError(f"unknown similarity {sim!r}")


def equivalent_overlap(sim: str, tau: float, len_r, len_s):
    """Minimum overlap needed for sim(r,s) >= tau (Table 1, real-valued).

    Comparing an integer overlap ``o >= equivalent_overlap(...)`` is exactly
    equivalent to ``sim >= tau`` (monotone transformations; no rounding is
    applied so there is no off-by-one risk).
    """
    if sim == OVERLAP:
        return tau + 0.0 * (len_r + len_s)  # broadcast like inputs
    if sim == JACCARD:
        return tau / (1.0 + tau) * (len_r + len_s)
    if sim == COSINE:
        return tau * (len_r * 1.0 * len_s) ** 0.5
    if sim == DICE:
        return tau * (len_r + len_s) / 2.0
    raise ValueError(f"unknown similarity {sim!r}")


def required_overlap(sim: str, tau: float, lr, ls):
    """float32, jnp-native twin of :func:`equivalent_overlap`.

    This is the single source of truth for the threshold used on device —
    inside the Pallas candidate/count kernels, the ring join's verification
    and the pure-jnp kernel oracles all call this one function, so every
    device path rounds the same way.  (:func:`equivalent_overlap` stays the
    dtype-polymorphic host/numpy version; both compute the Table 1 formulas.)
    """
    lr = jnp.asarray(lr).astype(jnp.float32)
    ls = jnp.asarray(ls).astype(jnp.float32)
    if sim == OVERLAP:
        return jnp.full_like(lr + ls, float(tau))
    if sim == JACCARD:
        return (tau / (1.0 + tau)) * (lr + ls)
    if sim == COSINE:
        return tau * jnp.sqrt(lr * ls)
    if sim == DICE:
        return (tau / 2.0) * (lr + ls)
    raise ValueError(f"unknown similarity {sim!r}")


# ---------------------------------------------------------------------------
# Length filter bounds (Table 2)
# ---------------------------------------------------------------------------

def length_bounds(sim: str, tau: float, len_r):
    """(lower, upper) real-valued bounds on |s| for sim(r,s) >= tau."""
    if sim == OVERLAP:
        lower = tau + 0.0 * len_r
        upper = np.inf + 0.0 * len_r
    elif sim == JACCARD:
        lower = len_r * tau
        upper = len_r / tau
    elif sim == COSINE:
        lower = len_r * tau * tau
        upper = len_r / (tau * tau)
    elif sim == DICE:
        lower = len_r * tau / (2.0 - tau)
        upper = len_r * (2.0 - tau) / tau
    else:
        raise ValueError(f"unknown similarity {sim!r}")
    return lower, upper


def length_window_int(sim: str, tau: float, len_r):
    """Integer-exact admissible |s| window per |r|: (ceil(lower), floor(upper)).

    For integer |s| the real-valued Table 2 window ``lower <= |s| <= upper``
    is exactly ``ceil(lower) <= |s| <= floor(upper)``.  Computing the integer
    bounds once (in float64, on host) lets device code apply the window with
    pure int32 comparisons — bit-identical to the host path's float
    comparison, with only O(block) scalars shipped instead of a dense mask.
    """
    lo, hi = length_bounds(sim, tau, np.asarray(len_r, dtype=np.float64))
    lo_i = np.maximum(np.ceil(lo), 0.0)
    int32_max = float(np.iinfo(np.int32).max)
    hi_i = np.where(np.isfinite(hi), np.floor(hi), int32_max)
    return (np.minimum(lo_i, int32_max).astype(np.int32),
            np.minimum(hi_i, int32_max).astype(np.int32))


# ---------------------------------------------------------------------------
# Prefix lengths (Table 2), integer-valued
# ---------------------------------------------------------------------------

def prefix_length(sim: str, tau: float, n):
    """Prefix size for a set of size ``n`` (1-overlap prefix schema)."""
    n = np.asarray(n)
    if sim == OVERLAP:
        p = n - tau + 1
    elif sim == JACCARD:
        p = np.floor((1.0 - tau) * n) + 1
    elif sim == COSINE:
        p = np.floor((1.0 - tau * tau) * n) + 1
    elif sim == DICE:
        p = np.floor((1.0 - tau / (2.0 - tau)) * n) + 1
    else:
        raise ValueError(f"unknown similarity {sim!r}")
    return np.minimum(np.maximum(p, 0), n).astype(np.int64)


def prefix_length_ell(sim: str, tau: float, n, ell: int):
    """ℓ-prefix schema (Section 2.3.5): prefix_ℓ(r) = |r| - τ_o(r,r') + ℓ.

    For non-overlap similarities the equivalent overlap depends on the
    partner's size; the safe (maximal) prefix uses the minimal equivalent
    overlap over the admissible length window, which for Jaccard reduces to
    the usual ``|r| - ceil(2τ/(1+τ)·|r|) + ℓ`` self-join form.
    """
    n = np.asarray(n)
    base = prefix_length(sim, tau, n)
    return np.minimum(base + (ell - 1), n).astype(np.int64)


# ---------------------------------------------------------------------------
# Eq. 2 — the bitmap overlap upper bound
# ---------------------------------------------------------------------------

def overlap_upper_bound(len_r, len_s, hamming):
    """⌊(|r| + |s| - popcount(b_r ⊕ b_s)) / 2⌋ (Theorem 1)."""
    return (len_r + len_s - hamming) // 2


def positional_upper_bound(len_r, len_s, pos_r, pos_s):
    """Positional filter bound (Section 2.3.3).

    Given the 0-based positions of the first common prefix token in r and s,
    the overlap can be at most 1 + min(remaining suffix lengths).
    """
    return 1 + np.minimum(len_r - pos_r - 1, len_s - pos_s - 1)
