"""Similarity functions, threshold conversions and the Eq. 2 upper bound.

Implements Table 1 (similarity functions + equivalent overlap), Table 2
(length bounds + prefix lengths) and Theorem 1 / Eq. 2 (the bitmap overlap
upper bound).  Everything is dtype-polymorphic: works on numpy arrays, python
scalars and jnp arrays (all ops are elementwise).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.core.constants import COSINE, DICE, JACCARD, OVERLAP


# ---------------------------------------------------------------------------
# Similarity functions (Table 1)
# ---------------------------------------------------------------------------

def similarity(sim: str, overlap, len_r, len_s):
    """sim(r, s) given |r ∩ s| and the set sizes."""
    o = overlap
    if sim == OVERLAP:
        return o
    if sim == JACCARD:
        return o / (len_r + len_s - o)
    if sim == COSINE:
        return o / (len_r * 1.0 * len_s) ** 0.5
    if sim == DICE:
        return 2.0 * o / (len_r + len_s)
    raise ValueError(f"unknown similarity {sim!r}")


def equivalent_overlap(sim: str, tau: float, len_r, len_s):
    """Minimum overlap needed for sim(r,s) >= tau (Table 1, real-valued).

    Comparing an integer overlap ``o >= equivalent_overlap(...)`` is exactly
    equivalent to ``sim >= tau`` (monotone transformations; no rounding is
    applied so there is no off-by-one risk).
    """
    if sim == OVERLAP:
        return tau + 0.0 * (len_r + len_s)  # broadcast like inputs
    if sim == JACCARD:
        return tau / (1.0 + tau) * (len_r + len_s)
    if sim == COSINE:
        return tau * (len_r * 1.0 * len_s) ** 0.5
    if sim == DICE:
        return tau * (len_r + len_s) / 2.0
    raise ValueError(f"unknown similarity {sim!r}")


def min_overlap_int(sim: str, tau: float, len_r, len_s):
    """Smallest *integer* overlap the oracle accepts for (|r|, |s|).

    ``o >= equivalent_overlap(...)`` with integer ``o`` is exactly
    ``o >= ceil(equivalent_overlap(...))`` — this is that ceiling, computed
    in the same float64 expression the oracle compares against, so every
    verification path that consumes it decides membership bit-identically
    to :func:`repro.core.join.naive_join`.
    """
    need = equivalent_overlap(sim, tau, np.asarray(len_r, dtype=np.int64),
                              np.asarray(len_s, dtype=np.int64))
    return np.ceil(need).astype(np.int64)


@functools.lru_cache(maxsize=64)
def min_overlap_table(sim: str, tau: float, lr_max: int, ls_max: int):
    """Device-gatherable :func:`min_overlap_int` table (int32, host-built).

    Devices run float32 (x64 off), where re-deriving the Table 1 threshold
    lands a few ulps off the oracle's float64 value and flips membership of
    exactly-at-threshold pairs.  The thresholds only depend on a small
    integer key — ``|r| + |s|`` for Jaccard/Dice, ``|r| * |s|`` for Cosine,
    nothing for Overlap — so each verification site gathers the exact
    integer threshold instead of recomputing it.  Cached per
    ``(sim, tau, lr_max, ls_max)`` with a bounded LRU (cosine tables can be
    hundreds of MB near the key-space guard; an unbounded cache would pin
    one per tau across a sweep).  Index with :func:`min_overlap_gather`;
    device code should go through the cached device twin
    ``repro.core.verify.min_overlap_table_dev`` rather than re-uploading.
    """
    if sim == COSINE:
        # Cosine thresholds key on |r|·|s|: the table is O(lr_max·ls_max).
        # Guard the key space so absurd padded widths fail loudly here
        # instead of exhausting memory (and so the gather index always
        # fits int32 — a wrapped index would gather garbage thresholds).
        if lr_max * ls_max + 1 > (1 << 27):
            raise ValueError(
                f"cosine min-overlap table key space {lr_max}x{ls_max} "
                f"exceeds 2^27 entries; shard or narrow the collections")
        key = np.arange(lr_max * ls_max + 1, dtype=np.int64)
        need = tau * (key * 1.0) ** 0.5
    elif sim == OVERLAP:
        key = np.arange(lr_max + ls_max + 1, dtype=np.int64)
        need = tau + 0.0 * key
    elif sim == JACCARD:
        key = np.arange(lr_max + ls_max + 1, dtype=np.int64)
        need = tau / (1.0 + tau) * key
    elif sim == DICE:
        key = np.arange(lr_max + ls_max + 1, dtype=np.int64)
        need = tau * key / 2.0
    else:
        raise ValueError(f"unknown similarity {sim!r}")
    tab = np.maximum(np.ceil(need), 0.0)
    return np.minimum(tab, np.iinfo(np.int32).max).astype(np.int32)


def min_overlap_gather(sim: str, table, len_r, len_s):
    """Gather the integer acceptance threshold per pair (jnp-traceable).

    ``table`` comes from :func:`min_overlap_table` (as a device array);
    ``len_r``/``len_s`` are int arrays.  Comparing an exact integer overlap
    ``o >= min_overlap_gather(...)`` reproduces the float64 oracle's
    verdict on device with pure int32 arithmetic.
    """
    len_r = jnp.asarray(len_r).astype(jnp.int32)
    len_s = jnp.asarray(len_s).astype(jnp.int32)
    idx = len_r * len_s if sim == COSINE else len_r + len_s
    return table[idx]


def required_overlap(sim: str, tau: float, lr, ls):
    """float32, jnp-native twin of :func:`equivalent_overlap`.

    This is the single source of truth for the threshold used on device —
    inside the Pallas candidate/count kernels, the ring join's verification
    and the pure-jnp kernel oracles all call this one function, so every
    device path rounds the same way.  (:func:`equivalent_overlap` stays the
    dtype-polymorphic host/numpy version; both compute the Table 1 formulas.)

    float32 rounding can land a few ulps *above* the float64 oracle value,
    so **pruning** decisions (the only thing a float threshold may decide)
    must compare against :func:`required_overlap_safe`, never this raw
    value; **acceptance** decisions use the integer
    :func:`min_overlap_table` machinery instead.
    """
    lr = jnp.asarray(lr).astype(jnp.float32)
    ls = jnp.asarray(ls).astype(jnp.float32)
    if sim == OVERLAP:
        return jnp.full_like(lr + ls, float(tau))
    if sim == JACCARD:
        return (tau / (1.0 + tau)) * (lr + ls)
    if sim == COSINE:
        return tau * jnp.sqrt(lr * ls)
    if sim == DICE:
        return (tau / 2.0) * (lr + ls)
    raise ValueError(f"unknown similarity {sim!r}")


def required_overlap_safe(sim: str, tau: float, lr, ls):
    """Prune-side lower bound on the float64 equivalent overlap.

    The float32 :func:`required_overlap` value can land a few ulps *above*
    the oracle's float64 threshold; a filter that prunes on ``bound <
    need_f32`` would then drop exactly-at-threshold true pairs.  Relaxing
    the threshold by a ≤1e-6 relative margin makes every float32 prune a
    strict subset of the float64 one — the slack only ever admits a handful
    of extra boundary candidates, which exact (integer) verification
    removes.  Use this in every upper-bound *prune* comparison; acceptance
    goes through :func:`min_overlap_table`.
    """
    need = required_overlap(sim, tau, lr, ls)
    return need * (1.0 - 1e-6) - 1e-6


# ---------------------------------------------------------------------------
# Length filter bounds (Table 2)
# ---------------------------------------------------------------------------

def length_bounds(sim: str, tau: float, len_r):
    """(lower, upper) real-valued bounds on |s| for sim(r,s) >= tau."""
    if sim == OVERLAP:
        lower = tau + 0.0 * len_r
        upper = np.inf + 0.0 * len_r
    elif sim == JACCARD:
        lower = len_r * tau
        upper = len_r / tau
    elif sim == COSINE:
        lower = len_r * tau * tau
        upper = len_r / (tau * tau)
    elif sim == DICE:
        lower = len_r * tau / (2.0 - tau)
        upper = len_r * (2.0 - tau) / tau
    else:
        raise ValueError(f"unknown similarity {sim!r}")
    return lower, upper


def length_window_int(sim: str, tau: float, len_r):
    """Integer-exact admissible partner-size window per |r|.

    This is the single source of truth for the length filter: every host
    and device path (``core/filters``, the blocked driver's block
    early-outs, the CPU algorithms' sorted-list breaks, the postings-index
    narrowing) derives its window from here, so none of them can drift
    from the others — or from verification.

    The float Table 2 bounds are only the starting guess: ``ceil``/``floor``
    of e.g. ``5 * 0.8 == 4.0000000000000002`` would exclude a partner that
    exact verification accepts (the window algebra is symmetric in exact
    arithmetic, but float rounding breaks the symmetry on boundaries).
    Each side is therefore corrected against the *need* test itself — a
    partner size ``m`` is admissible iff the best achievable overlap
    ``min(|r|, m)`` reaches :func:`equivalent_overlap` — which is precisely
    the test verification applies.  Float drift is sub-ulp, so the exact
    integer boundary is always within one of the float one.
    """
    n = np.asarray(len_r, dtype=np.int64)
    lo, hi = length_bounds(sim, tau, n.astype(np.float64))
    int32_max = np.int64(np.iinfo(np.int32).max)
    lo_i = np.maximum(np.ceil(lo), 0.0).astype(np.int64)
    lo_i = np.minimum(lo_i, int32_max)
    hi_i = np.where(np.isfinite(hi), np.floor(hi), float(int32_max))
    hi_i = np.minimum(hi_i, float(int32_max)).astype(np.int64)

    def admissible(m):
        ok = (m >= 1) & (n >= 1)
        need = equivalent_overlap(sim, tau, n, m)
        return ok & (np.minimum(n, m) >= need)

    # Widen (never shrink — a loose window only admits candidates that
    # verification re-checks) each side by the at-most-one integer the
    # float guess can be off.
    lo_i = np.where(admissible(lo_i - 1), lo_i - 1, lo_i)
    hi_i = np.where(admissible(hi_i + 1), hi_i + 1, hi_i)
    return (np.minimum(lo_i, int32_max).astype(np.int32),
            np.minimum(hi_i, int32_max).astype(np.int32))


# ---------------------------------------------------------------------------
# Prefix lengths (Table 2), integer-valued
# ---------------------------------------------------------------------------

def prefix_length(sim: str, tau: float, n):
    """Prefix size for a set of size ``n`` (1-overlap prefix schema).

    Derived from the oracle's own acceptance test instead of the raw Table 2
    float algebra: the minimal overlap any oracle-accepted partner can have
    is ``o_min = ceil(equivalent_overlap(n, lo))`` at the smallest
    admissible partner size ``lo`` (the need is nondecreasing in the partner
    size), and the pigeonhole prefix is ``n - o_min + 1``.  In exact
    arithmetic this equals the Table 2 closed forms (e.g. Jaccard
    ``floor((1 - tau) n) + 1``); computed via floats the closed forms drift
    on boundaries — ``floor((1 - 0.8) * 5) + 1 == 1`` instead of 2 — and a
    too-short prefix silently loses exactly-at-threshold pairs.  Because
    ``ceil`` is applied to the *same* float64 need that verification
    compares against, the result is the true minimal oracle-acceptable
    integer overlap, no rounding slack needed.
    """
    n_arr = np.asarray(n, dtype=np.int64)
    if sim not in (OVERLAP, JACCARD, COSINE, DICE):
        raise ValueError(f"unknown similarity {sim!r}")
    lo, _hi = length_window_int(sim, tau, np.maximum(n_arr, 1))
    o_min_f = equivalent_overlap(sim, tau, n_arr, np.maximum(lo.astype(np.int64), 1))
    o_min = np.maximum(np.ceil(o_min_f), 1.0)
    p = n_arr - o_min + 1
    return np.minimum(np.maximum(p, 0), n_arr).astype(np.int64)


def prefix_length_ell(sim: str, tau: float, n, ell: int):
    """ℓ-prefix schema (Section 2.3.5): prefix_ℓ(r) = |r| - τ_o(r,r') + ℓ.

    For non-overlap similarities the equivalent overlap depends on the
    partner's size; the safe (maximal) prefix uses the minimal equivalent
    overlap over the admissible length window, which for Jaccard reduces to
    the usual ``|r| - ceil(2τ/(1+τ)·|r|) + ℓ`` self-join form.
    """
    n = np.asarray(n)
    base = prefix_length(sim, tau, n)
    return np.minimum(base + (ell - 1), n).astype(np.int64)


# ---------------------------------------------------------------------------
# Eq. 2 — the bitmap overlap upper bound
# ---------------------------------------------------------------------------

def overlap_upper_bound(len_r, len_s, hamming):
    """⌊(|r| + |s| - popcount(b_r ⊕ b_s)) / 2⌋ (Theorem 1)."""
    return (len_r + len_s - hamming) // 2


def positional_upper_bound(len_r, len_s, pos_r, pos_s):
    """Positional filter bound (Section 2.3.3).

    Given the 0-based positions of the first common prefix token in r and s,
    the overlap can be at most 1 + min(remaining suffix lengths).
    """
    return 1 + np.minimum(len_r - pos_r - 1, len_s - pos_s - 1)


def positional_upper_bound_int(len_r, len_s, pos_r, pos_s):
    """int32, jnp-native twin of :func:`positional_upper_bound`.

    Same relationship as :func:`required_overlap` to
    :func:`equivalent_overlap`: this is the copy the device kernels trace
    (``np.minimum`` would force a host transfer under jit), computing the
    identical Section 2.3.3 bound.
    """
    len_r = jnp.asarray(len_r).astype(jnp.int32)
    len_s = jnp.asarray(len_s).astype(jnp.int32)
    pos_r = jnp.asarray(pos_r).astype(jnp.int32)
    pos_s = jnp.asarray(pos_s).astype(jnp.int32)
    return 1 + jnp.minimum(len_r - pos_r - 1, len_s - pos_s - 1)
