"""Expected overlap upper bounds and cutoff points (paper Sections 3.4-3.5).

Closed forms for the expected overlap upper bound E(b, n) between two *random*
(disjoint-by-chance) sets of ``n`` tokens hashed into ``b``-bit bitmaps:

* Eq. 4 (Bitmap-Set):   E = n + (b-1)^{2n}/b^{2n-1} - (b-1)^n/b^{n-1}
* Eq. 5 (Bitmap-Xor):   E = n - b/2 * P(odd #tokens hash to a bit over 2n draws)
                          = n - b/2 * (1 - (1 - 2/b)^{2n}) / 2 * 2
  (we use the parity closed form (1-(1-2/b)^{2n})/2, equal to the paper's
  binomial sum — verified against the explicit sum in tests)
* Eq. 6 (Bitmap-Next):  E = min(n^2 / b, n)

From these the **cutoff point** omega(b, tau) — the largest set size at which
the filter still discriminates at Jaccard threshold tau — and the
**Bitmap-Combined** crossovers are derived numerically.

All computations are done in log space where needed so they stay stable for
the n ~ 10^4, b ~ 4096 regime plotted in Fig. 6 of the paper.
"""

from __future__ import annotations

import functools
import math

import numpy as np

from repro.core.constants import BITMAP_NEXT, BITMAP_SET, BITMAP_XOR


def expected_bound_set(b: int, n: np.ndarray | int) -> np.ndarray:
    """Eq. 4 — expected overlap upper bound for Bitmap-Set ("mark")."""
    n = np.asarray(n, dtype=np.float64)
    # (b-1)^{kn} / b^{kn-1} = b * ((b-1)/b)^{kn}; do it in log space.
    log_q = math.log((b - 1) / b)
    term1 = np.exp(math.log(b) + 2.0 * n * log_q)  # b * q^{2n}
    term2 = np.exp(math.log(b) + n * log_q)  # b * q^{n}
    return n + term1 - term2


def expected_bound_xor(b: int, n: np.ndarray | int) -> np.ndarray:
    """Eq. 5 — expected overlap upper bound for Bitmap-Xor.

    P(bit differs) = P(odd number of the 2n tokens hash to it)
                   = (1 - (1 - 2/b)^{2n}) / 2      (binomial parity identity)
    E[hamming] = b * P;  bound = n - E[hamming]/2.
    """
    n = np.asarray(n, dtype=np.float64)
    p_odd = 0.5 * (1.0 - np.power(1.0 - 2.0 / b, 2.0 * n))
    return n - 0.5 * b * p_odd


def expected_bound_xor_sum(b: int, n: int) -> float:
    """Eq. 5 exactly as printed (explicit odd-k binomial sum). O(n) terms.

    Used in tests to confirm the parity closed form above.
    """
    total = 0.0
    for k in range(1, 2 * n + 1, 2):
        total += math.comb(2 * n, k) * (1.0 / b) ** k * ((b - 1.0) / b) ** (2 * n - k)
    return n - 0.5 * b * total


def expected_bound_next(b: int, n: np.ndarray | int) -> np.ndarray:
    """Eq. 6 — expected overlap upper bound for Bitmap-Next."""
    n = np.asarray(n, dtype=np.float64)
    return np.minimum(n * n / b, n)


_EXPECTED = {
    BITMAP_SET: expected_bound_set,
    BITMAP_XOR: expected_bound_xor,
    BITMAP_NEXT: expected_bound_next,
}


def expected_bound(method: str, b: int, n: np.ndarray | int) -> np.ndarray:
    return _EXPECTED[method](b, n)


def jaccard_of_overlap(o: np.ndarray, n: np.ndarray) -> np.ndarray:
    """Equivalent Jaccard of an overlap ``o`` between two size-``n`` sets."""
    o = np.asarray(o, dtype=np.float64)
    n = np.asarray(n, dtype=np.float64)
    denom = np.maximum(2.0 * n - o, 1e-300)
    return o / denom


@functools.lru_cache(maxsize=None)
def cutoff_point(method: str, b: int, tau_jaccard: float, n_max: int = 1 << 22) -> int:
    """omega(b, tau): max n such that the *expected* bound still prunes.

    Defined (Section 3.5) by E(b, n) == tau on the normalised scale; we return
    the largest ``n`` whose expected equivalent-Jaccard bound is <= tau.
    E-jaccard is monotonically increasing in n for all three methods, so a
    binary search suffices.
    """

    def ejac(n: int) -> float:
        return float(jaccard_of_overlap(expected_bound(method, b, n), n))

    if ejac(1) > tau_jaccard:
        return 0
    lo, hi = 1, 2
    while hi < n_max and ejac(hi) <= tau_jaccard:
        lo, hi = hi, hi * 2
    hi = min(hi, n_max)
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if ejac(mid) <= tau_jaccard:
            lo = mid
        else:
            hi = mid
    return lo


@functools.lru_cache(maxsize=None)
def combined_crossovers(b: int, grid: int = 400) -> tuple[float, float]:
    """Thresholds where the best generation method changes (Algorithm 6).

    Returns ``(lo, hi)``: Bitmap-Next wins for tau <= lo, Bitmap-Set for
    lo < tau < hi, Bitmap-Xor for tau >= hi.  The paper reports ~(0.56, 0.73)
    for b >= 64; we recompute from Eq. 4-6.
    """
    taus = np.linspace(0.05, 0.99, grid)
    best = []
    for t in taus:
        cuts = {m: cutoff_point(m, b, float(t)) for m in (BITMAP_SET, BITMAP_XOR, BITMAP_NEXT)}
        best.append(max(cuts, key=lambda m: cuts[m]))
    lo = 0.0
    hi = 1.0
    for t, m in zip(taus, best):
        if m == BITMAP_NEXT:
            lo = max(lo, float(t))
    for t, m in zip(taus, best):
        if m == BITMAP_XOR:
            hi = min(hi, float(t))
            break
    # Guard: degenerate grids (tiny b) — keep ordering sane.
    if hi < lo:
        lo = hi
    return lo, hi


def monte_carlo_expected_bound(
    method: str,
    b: int,
    n: int,
    trials: int = 2000,
    seed: int = 0,
) -> float:
    """Empirical E(b, n) via random disjoint pairs (paper's validation, §3.4).

    Tokens are drawn uniformly from a large universe; the expected *bound*
    (Eq. 2) is averaged over random pairs.  Matches the closed forms to
    <0.1% at the paper's settings (tested).
    """
    from repro.core import bitmap as bm  # local import: keep numpy-only users light
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    universe = 1 << 30
    toks = rng.integers(0, universe, size=(2 * trials, n), dtype=np.int64)
    # Make rows unique tokens (collisions in the draw are negligible but be safe).
    toks = np.sort(toks, axis=1).astype(np.int32)
    lengths = np.full((2 * trials,), n, dtype=np.int32)
    words = bm.generate_bitmaps(jnp.asarray(toks), jnp.asarray(lengths), b, method=method)
    words = np.asarray(words)
    wr, ws = words[:trials], words[trials:]
    x = wr ^ ws
    # numpy popcount via uint8 view lookup
    lut = np.unpackbits(np.arange(256, dtype=np.uint8)[:, None], axis=1).sum(1)
    ham = lut[x.view(np.uint8)].reshape(trials, -1).sum(axis=1)
    # Real-valued bound (no floor) to match the closed forms' expectation.
    bound = (2 * n - ham) / 2.0
    return float(bound.mean())


@functools.lru_cache(maxsize=None)
def combined_crossovers_normalized(b: int) -> tuple[float, float]:
    """The Algorithm 6 crossovers on the *normalised-overlap* scale.

    The paper states the Bitmap-Combined thresholds as (0.56, 0.73).  Careful
    reading of Section 3.5 (and checking against the Section 5.1.2 evidence —
    "Bitmap-Set is slightly better around tau_j = 0.5", Xor best for all
    tau_j >= 0.5 in Fig. 10) shows those constants live on the normalised
    overlap scale E/n of Fig. 5's *left* axis, not the Jaccard scale:
    tau_norm = 2*tau_j / (1 + tau_j).  :func:`combined_crossovers` returns the
    Jaccard-scale values (~0.39, ~0.57 for b >= 64), which map exactly onto
    the paper's (0.56, 0.73).  This helper returns the normalised-scale pair
    so benchmarks can validate the paper's constants directly.
    """
    lo_j, hi_j = combined_crossovers(b)
    to_norm = lambda tj: 2.0 * tj / (1.0 + tj)
    return to_norm(lo_j), to_norm(hi_j)
