"""Join planning: resolve (sim, tau, sizes, device availability) into an
explicit, inspectable :class:`JoinPlan`.

The paper separates bitmap *construction* (Section 3.2) from per-pair
*filtering*; the engine layer mirrors that split with a build-once
:class:`~repro.core.engine.PreparedCollection` artifact and a planner that
decides — once, up front, in one place — which driver runs a given workload
and with which knobs.  Every decision the drivers used to make implicitly
(bitmap method via Algorithm 6, cutoff via Eq. 4-6, block size, compaction
mode, capacity sizing) is written into the plan so callers can inspect,
log, serialize and override it.

Driver vocabulary:

* ``"naive"`` — the O(|R|·|S|) oracle; cheapest below a few thousand cells.
* ``"blocked"`` — the blocked device join (Algorithm 8, TPU-shaped).
* ``"ring"`` — the multi-device ring sweep (needs a mesh at execution time).
* ``"indexed"`` — CSR prefix-index candidate generation
  (:mod:`repro.index`); work scales with candidate count, not |R|·|S|.
* ``"sharded-indexed"`` — the same candidate path with the postings CSR cut
  into per-device token slabs (:mod:`repro.distributed.sharded_index`);
  needs a mesh at execution time.
* ``"allpairs" | "ppjoin" | "groupjoin" | "adaptjoin"`` — the faithful CPU
  algorithms with the pluggable Bitmap Filter.

``DRIVERS`` is the driver *registry*: the conformance suite
(``tests/test_driver_conformance.py``) derives its sweep from it, so a new
driver registered here cannot ship without oracle coverage.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

from repro.core import bitmap as bm
from repro.core import expected
from repro.core.constants import BITMAP_COMBINED, OVERLAP

DEVICE_DRIVERS = ("naive", "blocked", "ring", "indexed", "sharded-indexed")
CPU_DRIVERS = ("allpairs", "ppjoin", "groupjoin", "adaptjoin")
DRIVERS = DEVICE_DRIVERS + CPU_DRIVERS

#: What each driver guarantees when it runs under the segment-union join of
#: an appendable :class:`repro.store.CorpusStore` (base ∪ deltas vs a
#: from-scratch rebuild of the same rows):
#:
#: * ``"exact"``  — identical pairs AND identical summed funnel counters
#:   (``total_pairs`` / ``candidates`` / ``verified_true`` /
#:   ``candidates_generated``; plus ``postings_expanded`` on probes).
#:   Holds for the device drivers because those fields count per-pair
#:   predicates, which are invariant under partitioning the join grid by
#:   segments.
#: * ``"pairs"``  — identical pairs only.  The CPU algorithms' internal
#:   counters depend on collection *composition* (adaptjoin picks its
#:   prefix length per collection, groupjoin groups within a collection),
#:   so segment sums legitimately differ from the from-scratch run.
#:
#: Every driver in :data:`DRIVERS` must appear here — enforced by the
#: conformance suite (``tests/test_driver_conformance.py``), so a new
#: driver cannot ship without declaring its store behavior.
STORE_SUPPORT = {
    **{d: "exact" for d in DEVICE_DRIVERS},
    **{d: "pairs" for d in CPU_DRIVERS},
}


def _pow2_at_least(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


@dataclasses.dataclass(frozen=True)
class JoinPlan:
    """A fully-resolved join configuration.

    Immutable and JSON-able; produced by :class:`JoinPlanner` (or built by
    hand) and executed by :class:`~repro.core.engine.JoinEngine`.  ``reasons``
    records why each load-bearing choice was made.
    """

    driver: str
    sim: str
    tau: float
    b: int = 128
    method: str = BITMAP_COMBINED   # resolved: never 'combined' after planning
    mix: bool = False
    block: int = 4096               # block size / indexed probe-chunk size
    compaction: str = "host"        # 'host' | 'device' (blocked driver only)
    capacity: Optional[int] = None  # None -> prepass-sized per block pair
    impl: str = "auto"
    use_cutoff: bool = True
    cutoff: int = 1 << 30           # resolved Eq. 4-6 cutoff (informational)
    ell: int = 1                    # indexed driver: ℓ-prefix index schema
    reasons: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.driver not in DRIVERS:
            raise ValueError(f"unknown driver {self.driver!r}; one of {DRIVERS}")
        if self.compaction not in ("host", "device"):
            raise ValueError(f"compaction must be 'host' or 'device', "
                             f"got {self.compaction!r}")
        if self.b <= 0 or self.b % 32:
            raise ValueError(f"bitmap width b={self.b} must be a positive "
                             f"multiple of 32")
        if self.block <= 0:
            raise ValueError(f"block size must be positive, got {self.block}")
        if self.ell < 1:
            raise ValueError(f"ell must be >= 1, got {self.ell}")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["reasons"] = list(self.reasons)
        return d

    def describe(self) -> str:
        """Human-readable one-plan report (for logs / notebooks)."""
        head = (f"JoinPlan[{self.driver}] sim={self.sim} tau={self.tau} "
                f"b={self.b} method={self.method} mix={self.mix} "
                f"block={self.block} compaction={self.compaction} "
                f"capacity={self.capacity} cutoff={self.cutoff} "
                f"ell={self.ell}")
        return "\n".join([head] + [f"  - {r}" for r in self.reasons])

    def to_json(self) -> str:
        return json.dumps(self.to_dict())


class JoinPlanner:
    """Resolve workload shape + device availability into a :class:`JoinPlan`.

    Heuristics are deterministic and documented via ``JoinPlan.reasons``:

    * tiny cross products run the ``naive`` oracle (no artifact pays off);
    * multi-device meshes get ``sharded-indexed`` when the same
      ``indexed_cells`` / ``indexed_min_tau`` conditions hold that justify
      the index on one device (per-device token slabs beat re-walking the
      grid on every device), and the ``ring`` sweep otherwise;
    * single-device workloads whose grid exceeds ``indexed_cells`` at a
      threshold high enough for selective prefixes (``tau >=
      indexed_min_tau``, normalised similarities only) get the ``indexed``
      driver — candidate generation from the CSR prefix index instead of
      the quadratic grid;
    * everything else gets the ``blocked`` driver; accelerators use
      device-resident compaction, CPUs host compaction (``np.nonzero`` on
      host is the fast path there);
    * ``prefer="cpu"`` selects a faithful CPU algorithm — AdaptJoin below the
      Jaccard-scale threshold where its ℓ-prefix schema pays (the paper's
      low-τ regime), PPJoin otherwise;
    * bitmap method comes from Algorithm 6 (:func:`repro.core.bitmap.
      choose_method`), the cutoff from Eq. 4-6.
    """

    def __init__(self, *, b: int = 128, block: int = 4096,
                 naive_cells: int = 4096, mix: bool = False,
                 use_cutoff: bool = True, impl: str = "auto",
                 adaptjoin_below_tau: float = 0.6,
                 indexed_cells: int = 1 << 25,
                 indexed_min_tau: float = 0.6):
        self.b = b
        self.block = block
        self.naive_cells = naive_cells
        self.mix = mix
        self.use_cutoff = use_cutoff
        self.impl = impl
        self.adaptjoin_below_tau = adaptjoin_below_tau
        self.indexed_cells = indexed_cells
        self.indexed_min_tau = indexed_min_tau

    def plan(self, sim: str, tau: float, n_r: int,
             n_s: Optional[int] = None, *,
             prefer: str = "auto",
             backend: Optional[str] = None,
             n_devices: Optional[int] = None,
             b: Optional[int] = None,
             block: Optional[int] = None) -> JoinPlan:
        """Resolve a plan for an ``n_r`` × ``n_s`` join (self-join if ``n_s``
        is omitted).

        ``backend``/``n_devices`` default to the live JAX runtime; pass them
        explicitly for deterministic planning in tests or offline tooling.
        ``prefer`` is ``"auto"`` | ``"device"`` | ``"cpu"``.
        """
        if prefer not in ("auto", "device", "cpu"):
            raise ValueError(f"prefer must be auto|device|cpu, got {prefer!r}")
        if n_r <= 0:
            raise ValueError(f"n_r must be positive, got {n_r}")
        if backend is None or n_devices is None:
            import jax
            backend = backend or jax.default_backend()
            n_devices = n_devices if n_devices is not None else jax.device_count()
        b = b or self.b
        reasons = []

        cells = n_r * (n_s if n_s is not None else n_r)
        if prefer != "cpu" and cells <= self.naive_cells:
            driver = "naive"
            reasons.append(
                f"naive: {cells} cells <= naive_cells={self.naive_cells}; "
                f"the O(N^2) oracle beats building join artifacts")
        elif prefer == "cpu":
            if sim != "overlap" and tau < self.adaptjoin_below_tau:
                driver = "adaptjoin"
                reasons.append(
                    f"adaptjoin: prefer=cpu and tau={tau} < "
                    f"{self.adaptjoin_below_tau} (ℓ-prefix schema pays at low τ)")
            else:
                driver = "ppjoin"
                reasons.append("ppjoin: prefer=cpu (positional filter is the "
                               "best general-purpose CPU prefix algorithm)")
        elif n_devices > 1:
            if (sim != OVERLAP and tau >= self.indexed_min_tau
                    and cells > self.indexed_cells):
                driver = "sharded-indexed"
                reasons.append(
                    f"sharded-indexed: {n_devices} devices and {cells} cells "
                    f"> indexed_cells={self.indexed_cells} at tau={tau} >= "
                    f"{self.indexed_min_tau} (selective prefixes); the CSR "
                    f"postings shard into per-device token slabs, so "
                    f"candidate generation scales with devices instead of "
                    f"re-walking the grid")
            else:
                driver = "ring"
                reasons.append(
                    f"ring: {n_devices} devices available; R shards stay "
                    f"resident, S circulates via collective_permute "
                    f"(grid too small or tau too low for sharded postings)")
        elif (sim != OVERLAP and tau >= self.indexed_min_tau
              and cells > self.indexed_cells):
            driver = "indexed"
            reasons.append(
                f"indexed: {cells} cells > indexed_cells="
                f"{self.indexed_cells} and tau={tau} >= "
                f"{self.indexed_min_tau} (selective prefixes); CSR "
                f"prefix-index candidate generation scales with candidates, "
                f"not |R|x|S|")
        else:
            driver = "blocked"
            reasons.append("blocked: single device; blocked length-sorted "
                           "walk with fused bitmap-filter tiles")

        on_accelerator = backend in ("tpu", "gpu")
        compaction = "device" if on_accelerator else "host"
        reasons.append(
            f"compaction={compaction}: backend={backend} "
            + ("(keep candidate lists resident, ship only compacted pairs)"
               if on_accelerator else
               "(dense np.nonzero on host is the fast path on CPU)"))

        if block is None:
            largest = max(n_r, n_s or n_r)
            block = min(self.block, max(128, _pow2_at_least(largest)))
        reasons.append(f"block={block}: min(default {self.block}, pow2 cover "
                       f"of max collection size)")

        if tau <= 0 and sim != "overlap":
            raise ValueError(f"tau must be positive for sim={sim!r}, got {tau}")
        method = bm.choose_method(float(tau), b)
        reasons.append(f"method={method}: Algorithm 6 crossovers at b={b}, "
                       f"tau={tau}")
        cutoff = (expected.cutoff_point(method, b, float(tau))
                  if self.use_cutoff else 1 << 30)
        reasons.append(f"cutoff={cutoff}: Eq. 4-6 expected bound "
                       + ("" if self.use_cutoff else "(disabled)"))

        return JoinPlan(
            driver=driver, sim=sim, tau=float(tau), b=b, method=method,
            mix=self.mix, block=block, compaction=compaction, capacity=None,
            impl=self.impl, use_cutoff=self.use_cutoff, cutoff=int(cutoff),
            reasons=tuple(reasons))

    def serving_plan(self, sim: str, tau: float, n_r: int, *,
                     b: Optional[int] = None,
                     block: Optional[int] = None,
                     backend: Optional[str] = None) -> JoinPlan:
        """Resolve a plan for a *resident serving session*
        (:class:`repro.serve.JoinSession`): many small probe batches against
        one long-lived corpus.

        The one-shot heuristics above size the driver to a single batch; a
        session amortizes its build artifacts over thousands of probes, so
        the postings-CSR ``indexed`` driver wins even below the one-shot
        ``indexed_cells`` floor — its per-probe work scales with candidate
        count, which is what sustains probes/sec.  ``overlap`` similarity
        (no normalised prefixes) falls back to the ``blocked`` driver; the
        session then serves it without the coalesced fast path.
        """
        if n_r <= 0:
            raise ValueError(f"n_r must be positive, got {n_r}")
        if tau <= 0 and sim != OVERLAP:
            raise ValueError(f"tau must be positive for sim={sim!r}, got {tau}")
        if backend is None:
            import jax
            backend = jax.default_backend()
        b = b or self.b
        block = block or self.block
        reasons = []
        if sim != OVERLAP and tau >= self.indexed_min_tau:
            driver = "indexed"
            reasons.append(
                f"indexed: resident session amortizes the postings CSR over "
                f"every probe; per-probe work scales with candidates, "
                f"not |R|x|batch| (tau={tau} >= {self.indexed_min_tau})")
        elif sim != OVERLAP:
            driver = "indexed"
            reasons.append(
                f"indexed: tau={tau} < indexed_min_tau="
                f"{self.indexed_min_tau} makes prefixes long, but a "
                f"resident session still amortizes the index build and "
                f"keeps the coalesced entrypoint path; expect a weaker "
                f"candidate-generation win")
        else:
            driver = "blocked"
            reasons.append("blocked: overlap similarity has no normalised "
                           "prefix schema for the postings index; the "
                           "session serves it without batch coalescing")
        compaction = "device" if backend in ("tpu", "gpu") else "host"
        reasons.append(f"compaction={compaction}: backend={backend}")
        method = bm.choose_method(float(tau), b)
        cutoff = (expected.cutoff_point(method, b, float(tau))
                  if self.use_cutoff else 1 << 30)
        reasons.append(f"method={method} cutoff={cutoff}: Algorithm 6 / "
                       f"Eq. 4-6 at b={b}, tau={tau}")
        return JoinPlan(
            driver=driver, sim=sim, tau=float(tau), b=b, method=method,
            mix=self.mix, block=block, compaction=compaction, capacity=None,
            impl=self.impl, use_cutoff=self.use_cutoff, cutoff=int(cutoff),
            reasons=tuple(reasons))
