"""Bitmap generation (paper Section 3.2) and bit packing utilities.

Three generation methods are implemented, all vectorised in JAX:

* **Bitmap-Set** (Algorithm 3): bit ``h(t)`` is OR-ed for every token.
* **Bitmap-Xor** (Algorithm 4): bit ``h(t)`` is XOR-ed for every token — a bit
  stays set iff an odd number of tokens hash to it.
* **Bitmap-Next** (Algorithm 5): linear probing — each token sets the first
  unset bit at or cyclically after ``h(t)``; the bitmap has exactly
  ``min(n, b)`` ones.

Bitmaps are stored **packed** as ``uint32[N, W]`` with ``W = b // 32``; bit
``i`` of set ``n`` lives at word ``i // 32``, bit ``i % 32`` (little-endian
within the word).  All public entry points accept the padded
:class:`~repro.core.collection.Collection` layout (``tokens`` int32[N, L] with
``PAD_TOKEN`` padding + ``lengths``).

The default hash is the paper's ``h(t) = t mod b`` (Section 5.1); an optional
multiplicative (Knuth) mixer is available for adversarial id distributions.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import expected
from repro.core.constants import (
    BITMAP_COMBINED,
    BITMAP_NEXT,
    BITMAP_SET,
    BITMAP_XOR,
    PAD_TOKEN,
)

_KNUTH = np.uint32(2654435761)


def hash_positions(tokens: jnp.ndarray, b: int, mix: bool = False) -> jnp.ndarray:
    """``h(t)``: map tokens to bit positions in ``[0, b)``.

    Args:
      tokens: int32[...] token ids (PAD_TOKEN allowed — callers mask validity).
      b: bitmap size in bits.
      mix: apply a multiplicative mixer before the modulo (off by default to
        match the paper's ``h(t) = t mod b``).
    """
    t = tokens.astype(jnp.uint32)
    if mix:
        t = t * _KNUTH
        t = t ^ (t >> jnp.uint32(16))
    return (t % jnp.uint32(b)).astype(jnp.int32)


def _bit_counts(tokens: jnp.ndarray, lengths: jnp.ndarray, b: int, mix: bool) -> jnp.ndarray:
    """int32[N, b] — how many (valid) tokens of each set hash to each bit."""
    n, l = tokens.shape
    pos = hash_positions(tokens, b, mix)
    valid = (tokens != PAD_TOKEN) & (jnp.arange(l)[None, :] < lengths[:, None])
    counts = jnp.zeros((n, b), dtype=jnp.int32)
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], (n, l))
    counts = counts.at[rows, pos].add(valid.astype(jnp.int32))
    return counts


@functools.partial(jax.jit, static_argnames=("b", "mix"))
def bitmap_set_bits(tokens: jnp.ndarray, lengths: jnp.ndarray, b: int, mix: bool = False) -> jnp.ndarray:
    """Bitmap-Set as a bool[N, b] bit matrix."""
    return _bit_counts(tokens, lengths, b, mix) > 0


@functools.partial(jax.jit, static_argnames=("b", "mix"))
def bitmap_xor_bits(tokens: jnp.ndarray, lengths: jnp.ndarray, b: int, mix: bool = False) -> jnp.ndarray:
    """Bitmap-Xor as a bool[N, b] bit matrix."""
    return (_bit_counts(tokens, lengths, b, mix) % 2) == 1


@functools.partial(jax.jit, static_argnames=("b", "mix"))
def bitmap_next_bits(tokens: jnp.ndarray, lengths: jnp.ndarray, b: int, mix: bool = False) -> jnp.ndarray:
    """Bitmap-Next as a bool[N, b] bit matrix.

    Linear probing is inherently sequential per set, so we ``lax.scan`` over
    the (padded) token axis and ``vmap`` over sets.  Each probe is resolved in
    O(b) branch-free work: among unset bits, pick the one minimising the
    cyclic distance ``(i - h(t)) mod b``.  Saturated bitmaps (n >= b) come out
    all-ones, matching Algorithm 5's early exit.
    """
    n, l = tokens.shape
    pos = hash_positions(tokens, b, mix)
    valid = (tokens != PAD_TOKEN) & (jnp.arange(l)[None, :] < lengths[:, None])
    idx = jnp.arange(b, dtype=jnp.int32)

    def per_set(pos_row: jnp.ndarray, valid_row: jnp.ndarray) -> jnp.ndarray:
        def step(bits, pv):
            p, v = pv
            dist = (idx - p) % b
            dist = jnp.where(bits, b, dist)  # occupied bits are never chosen
            j = jnp.argmin(dist)
            new_bits = bits.at[j].set(True)
            return jnp.where(v, new_bits, bits), None

        bits0 = jnp.zeros((b,), dtype=bool)
        bits, _ = jax.lax.scan(step, bits0, (pos_row, valid_row))
        return bits

    return jax.vmap(per_set)(pos, valid)


def _validate_width(b: int) -> None:
    """Reject widths that would silently mis-pack (b <= 0, or bits that do
    not fill whole uint32 words)."""
    if not isinstance(b, (int, np.integer)):
        raise ValueError(f"bitmap width must be an int, got {type(b).__name__}")
    if b <= 0 or b % 32:
        raise ValueError(
            f"bitmap width b={b} must be a positive multiple of 32 "
            f"(bitmaps are packed into uint32 words)")


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """bool[N, b] -> uint32[N, b//32] (little-endian bit order within words)."""
    n, b = bits.shape
    _validate_width(b)
    w = b // 32
    shaped = bits.reshape(n, w, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[None, None, :]
    return jnp.sum(shaped * weights, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jnp.ndarray, b: int | None = None) -> jnp.ndarray:
    """uint32[N, W] -> bool[N, 32*W]."""
    n, w = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = ((words[:, :, None] >> shifts) & jnp.uint32(1)).astype(bool)
    bits = bits.reshape(n, w * 32)
    if b is not None:
        bits = bits[:, :b]
    return bits


def popcount32(v: jnp.ndarray) -> jnp.ndarray:
    """SWAR population count on uint32 lanes (branch-free, VPU-friendly).

    TPUs have no scalar POPCNT; this is the classic bit-slice reduction that
    vectorises across the 8x128 vector unit. Returns uint32.
    """
    v = v.astype(jnp.uint32)
    v = v - ((v >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    v = (v + (v >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return (v * jnp.uint32(0x01010101)) >> jnp.uint32(24)


def popcount_rows(words: jnp.ndarray) -> jnp.ndarray:
    """uint32[N, W] -> int32[N] total ones per row."""
    return jnp.sum(popcount32(words).astype(jnp.int32), axis=-1)


_GENERATORS = {
    BITMAP_SET: bitmap_set_bits,
    BITMAP_XOR: bitmap_xor_bits,
    BITMAP_NEXT: bitmap_next_bits,
}


def choose_method(tau_jaccard: float, b: int = 64) -> str:
    """Bitmap-Combined policy (Algorithm 6), thresholds derived from Eq. 4-6.

    The paper hard-codes the crossovers (Next below ~0.56, Set in the middle,
    Xor above ~0.73) observed for b >= 64; we recompute them from the
    expected-bound equations so the policy stays correct for any ``b``.
    """
    lo, hi = expected.combined_crossovers(b)
    if tau_jaccard <= lo:
        return BITMAP_NEXT
    if tau_jaccard >= hi:
        return BITMAP_XOR
    return BITMAP_SET


def generate_bitmaps(
    tokens: jnp.ndarray,
    lengths: jnp.ndarray,
    b: int,
    method: str = BITMAP_COMBINED,
    tau_jaccard: float | None = None,
    mix: bool = False,
    packed: bool = True,
) -> jnp.ndarray:
    """Generate bitmaps for a padded collection.

    Args:
      tokens: int32[N, L] padded tokens.
      lengths: int32[N].
      b: bitmap width in bits (multiple of 32).
      method: 'set' | 'xor' | 'next' | 'combined'.
      tau_jaccard: required when method == 'combined'.
      packed: return packed uint32[N, b//32] (default) or bool[N, b].

    Raises:
      ValueError: if ``b`` is not a positive multiple of 32 (widths that
        would silently mis-pack into uint32 words), or for an unknown method.
    """
    _validate_width(b)
    if method == BITMAP_COMBINED:
        if tau_jaccard is None:
            raise ValueError("combined method needs tau_jaccard")
        method = choose_method(tau_jaccard, b)
    if method not in _GENERATORS:
        raise ValueError(f"unknown bitmap method {method!r}; "
                         f"one of {sorted(_GENERATORS)} or 'combined'")
    bits = _GENERATORS[method](tokens, lengths, b, mix)
    return pack_bits(bits) if packed else bits


def hamming_packed(words_r: jnp.ndarray, words_s: jnp.ndarray) -> jnp.ndarray:
    """Pairwise Hamming distance between two packed bitmap matrices.

    uint32[NR, W] x uint32[NS, W] -> int32[NR, NS].  Pure-jnp reference path
    (the Pallas kernels in ``repro.kernels`` implement the tiled version).
    """
    x = words_r[:, None, :] ^ words_s[None, :, :]
    return jnp.sum(popcount32(x).astype(jnp.int32), axis=-1)
