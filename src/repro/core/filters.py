"""Classic filters of the Filter-Verification framework (Section 2.3).

These are the building blocks the paper composes with its Bitmap Filter:
length filter (2.3.2), prefix filter (2.3.1), positional filter (2.3.3) and
the bitmap filter itself (Section 3.6, Algorithm 7) in both numpy and jnp
flavours.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitmap as bm
from repro.core import bounds, expected
from repro.core.constants import BITMAP_COMBINED


def length_window(sim: str, tau: float, len_r) -> tuple[np.ndarray, np.ndarray]:
    """Inclusive integer (lo, hi) admissible |s| window for the length filter.

    Routed through :func:`repro.core.bounds.length_window_int` — the single
    source of truth the device drivers use — so the host path can never
    drift from the integer-exact device path.  For integer |s| the window is
    identical to the real-valued Table 2 bounds (property-tested in
    ``tests/test_bounds_property.py``).
    """
    return bounds.length_window_int(sim, tau, len_r)


def length_filter_mask(sim: str, tau: float, len_r, len_s):
    """True where the pair *survives* the length filter (elementwise).

    Same integer-exact window as :func:`length_window` (and therefore the
    same test every device kernel applies).
    """
    lo, hi = bounds.length_window_int(sim, tau, len_r)
    return (len_s >= lo) & (len_s <= hi)


def positional_filter_mask(sim: str, tau: float, len_r, len_s, pos_r, pos_s):
    """True where the pair survives the positional filter."""
    ub = bounds.positional_upper_bound(len_r, len_s, pos_r, pos_s)
    need = bounds.equivalent_overlap(sim, tau, len_r, len_s)
    return ub >= need


@dataclasses.dataclass
class BitmapFilter:
    """Algorithm 7 — precomputed bitmaps + cutoff, reusable across probes.

    ``numpy`` flavour used by the faithful CPU algorithms; the device join in
    ``repro.core.join`` uses the Pallas kernels instead.

    For a self-join the probe and the index are the same collection.  For an
    R×S join build with :meth:`build_rs`: the index side holds R (the
    candidates), the probe side holds S; ``prune_mask(s, r_cands)`` then
    compares the probe set's bitmap against index-side bitmaps.  Both sides
    must share one token space (``h(t) = t mod b`` is token-value based, so
    bitmaps are comparable across collections).
    """

    words: np.ndarray  # uint32[N, W] packed bitmaps (index side)
    lengths: np.ndarray  # int32[N]
    sim: str
    tau: float
    b: int
    cutoff: int
    method: str
    probe_words: np.ndarray | None = None   # probe side; defaults to index side
    probe_lengths: np.ndarray | None = None

    # 8-bit popcount LUT shared by all instances.
    _LUT = np.unpackbits(np.arange(256, dtype=np.uint8)[:, None], axis=1).sum(1).astype(np.int32)

    def __post_init__(self):
        if self.probe_words is None:
            self.probe_words = self.words
        if self.probe_lengths is None:
            self.probe_lengths = self.lengths

    @classmethod
    def build(
        cls,
        tokens: np.ndarray,
        lengths: np.ndarray,
        sim: str,
        tau: float,
        b: int = 64,
        method: str = BITMAP_COMBINED,
        use_cutoff: bool = True,
        mix: bool = False,
    ) -> "BitmapFilter":
        import jax.numpy as jnp

        tau_j = tau  # cutoff policy is parameterised on the Jaccard scale
        if method == BITMAP_COMBINED:
            chosen = bm.choose_method(tau_j, b)
        else:
            chosen = method
        words = np.asarray(
            bm.generate_bitmaps(jnp.asarray(tokens), jnp.asarray(lengths), b,
                                method=chosen, mix=mix)
        )
        cutoff = expected.cutoff_point(chosen, b, float(tau_j)) if use_cutoff else np.iinfo(np.int32).max
        return cls(
            words=words,
            lengths=np.asarray(lengths),
            sim=sim,
            tau=tau,
            b=b,
            cutoff=int(cutoff),
            method=chosen,
        )

    @classmethod
    def build_rs(
        cls,
        tokens_r: np.ndarray,
        lengths_r: np.ndarray,
        tokens_s: np.ndarray,
        lengths_s: np.ndarray,
        sim: str,
        tau: float,
        b: int = 64,
        method: str = BITMAP_COMBINED,
        use_cutoff: bool = True,
        mix: bool = False,
    ) -> "BitmapFilter":
        """Cross-collection filter: index side R, probe side S."""
        import jax.numpy as jnp

        if method == BITMAP_COMBINED:
            chosen = bm.choose_method(tau, b)
        else:
            chosen = method
        words_r = np.asarray(bm.generate_bitmaps(
            jnp.asarray(tokens_r), jnp.asarray(lengths_r), b, method=chosen,
            mix=mix))
        words_s = np.asarray(bm.generate_bitmaps(
            jnp.asarray(tokens_s), jnp.asarray(lengths_s), b, method=chosen,
            mix=mix))
        cutoff = expected.cutoff_point(chosen, b, float(tau)) if use_cutoff else np.iinfo(np.int32).max
        return cls(
            words=words_r,
            lengths=np.asarray(lengths_r),
            sim=sim,
            tau=tau,
            b=b,
            cutoff=int(cutoff),
            method=chosen,
            probe_words=words_s,
            probe_lengths=np.asarray(lengths_s),
        )

    def hamming(self, i: int, js: np.ndarray) -> np.ndarray:
        """Hamming distances between probe set ``i`` and index sets ``js``."""
        x = self.probe_words[i][None, :] ^ self.words[js]
        return self._LUT[x.view(np.uint8)].reshape(len(js), -1).sum(axis=1)

    def prune_mask(self, i: int, js: np.ndarray) -> np.ndarray:
        """True where the pair (i, j) is *pruned* by the bitmap filter.

        ``i`` indexes the probe side, ``js`` the index side (identical for a
        self-join).  Mirrors Algorithm 7: above the cutoff the filter is a
        no-op.
        """
        js = np.asarray(js, dtype=np.int64)
        if len(js) == 0:
            return np.zeros((0,), dtype=bool)
        if self.probe_lengths[i] > self.cutoff:
            return np.zeros(js.shape, dtype=bool)
        ham = self.hamming(i, js)
        ub = bounds.overlap_upper_bound(self.probe_lengths[i], self.lengths[js], ham)
        need = bounds.equivalent_overlap(self.sim, self.tau,
                                         self.probe_lengths[i], self.lengths[js])
        return ub < need
