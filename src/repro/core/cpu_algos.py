"""Faithful reproductions of the four CPU algorithms the paper accelerates.

AllPairs [3], PPJoin [25], GroupJoin [4] and AdaptJoin [23], each with a
pluggable Bitmap Filter exactly where Section 4.1 inserts it:

* AllPairs / PPJoin / GroupJoin: bitmap test in the **verification loop**
  (``filter_3`` — once per unique candidate; for GroupJoin after group
  expansion);
* AdaptJoin: bitmap test at **candidate generation** (``filter_2``) during the
  1-prefix iteration.

These are numpy/python implementations (the originals are C++): absolute
runtimes are not comparable to the paper's Table 5, but the *relative*
improvement of +BF vs the original — the paper's actual claim — is, and is
what ``benchmarks/bench_cpu_algos.py`` measures.  All four return exactly the
oracle pair set (tested).

Every algorithm supports both the self-join (``algo(col, sim, tau)``) and the
paper's general two-collection R×S join (``algo(col_r, col_s, sim, tau)``):
the prefix index is built over R and probed with S, and the bitmap filter
(built with :meth:`BitmapFilter.build_rs` for R×S) runs at the same
``filter_2``/``filter_3`` points.

Self-join inputs must be preprocessed with
:func:`repro.core.collection.preprocess`, R×S inputs with
:func:`repro.core.collection.preprocess_rs` (a *shared* token-frequency
ordering across both collections — prefix-filter correctness needs a common
total order) — both the prefix filter's selectivity and the sorted-index
length early-out rely on it.

All four algorithms also accept
:class:`~repro.core.engine.PreparedCollection` inputs: the algorithm bodies
run over the prepared (length-sorted) view, the ℓ-prefix inverted index comes
from the prepared cache (built once per ``(sim, tau, ell)``), and the
returned pairs are remapped to original collection indices.  A ``bitmap=``
filter passed alongside prepared inputs must be built over the prepared
order — use :func:`repro.core.engine.prepared_bitmap_filter`.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import bounds, verify
from repro.core.collection import Collection, split_join_args
from repro.core.constants import JACCARD
from repro.core.engine import PreparedCollection
from repro.core.filters import BitmapFilter


@dataclasses.dataclass
class AlgoStats:
    candidates: int = 0           # pairs reaching the verification stage
    bitmap_pruned: int = 0        # pairs pruned by the Bitmap Filter
    verified: int = 0             # exact verifications executed
    results: int = 0


def _build_prefix_index(col: Collection, sim: str, tau: float,
                        ell: int = 1) -> Dict[int, List[Tuple[int, int]]]:
    """Inverted index over ℓ-prefixes: token -> [(set_id, position)].

    Lists are naturally sorted by set id == by length (collection is
    size-sorted), which the length filter's early-outs exploit.  A
    :class:`~repro.core.engine.PreparedCollection` answers from its cache
    (built at most once per ``(sim, tau, ell)``).
    """
    if isinstance(col, PreparedCollection):
        return col.prefix_index(sim, tau, ell)
    index: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
    for i in range(col.num_sets):
        n = int(col.lengths[i])
        p = _prefix_len(sim, tau, n, ell)
        for pos in range(p):
            index[int(col.tokens[i, pos])].append((i, pos))
    return index



@functools.lru_cache(maxsize=None)
def _int_window(sim: str, tau: float, n: int) -> Tuple[int, int]:
    """Scalar integer length window (single source of truth:
    :func:`repro.core.bounds.length_window_int` — the raw float bounds can
    exclude boundary partners that exact verification accepts).  Cached:
    the drift-corrected window costs ~10 numpy temporaries per call and
    sits in every probe loop; (sim, tau, n) keys repeat heavily."""
    lo, hi = bounds.length_window_int(sim, tau, n)
    return int(lo), int(hi)


@functools.lru_cache(maxsize=None)
def _prefix_len(sim: str, tau: float, n: int, ell: int = 1) -> int:
    """Cached scalar ℓ-prefix length (same caching rationale as
    :func:`_int_window`; :func:`repro.core.bounds.prefix_length` now routes
    through the corrected window and is no longer a two-flop closed form)."""
    return int(bounds.prefix_length_ell(sim, tau, n, ell))


@functools.lru_cache(maxsize=None)
def _min_overlap(sim: str, tau: float, lr: int, ls: int) -> int:
    """Cached scalar minimal oracle-accepted overlap (integer-exact
    acceptance, identical to ``o >= equivalent_overlap`` for integer o)."""
    return int(bounds.min_overlap_int(sim, tau, lr, ls))

def _verify_pair(col: Collection, r: int, s: int, sim: str, tau: float,
                 stats: AlgoStats) -> bool:
    stats.verified += 1
    need = _min_overlap(sim, tau, int(col.lengths[r]), int(col.lengths[s]))
    o = verify.overlap_early_terminate(col.row(r), col.row(s), need)
    return o >= need


def _verify_pair_rs(col_r: Collection, col_s: Collection, r: int, s: int,
                    sim: str, tau: float, stats: AlgoStats) -> bool:
    stats.verified += 1
    need = _min_overlap(sim, tau, int(col_r.lengths[r]), int(col_s.lengths[s]))
    o = verify.overlap_early_terminate(col_r.row(r), col_s.row(s), need)
    return o >= need


def _pack_pairs_rs(results: List[Tuple[int, int]]) -> np.ndarray:
    """(r_index, s_index) pairs — no i<j canonicalisation across collections."""
    if not results:
        return np.zeros((0, 2), dtype=np.int64)
    return np.asarray(sorted(set(results)), dtype=np.int64)


def _prepared_remapper(col, col_s):
    """Map result pairs from prepared (length-sorted) space back to original
    collection indices.

    The algorithm bodies run unchanged over a
    :class:`~repro.core.engine.PreparedCollection` (it duck-types the read
    surface of ``Collection`` over its sorted view), so their pair indices
    come out in sorted space; this remaps them through ``order`` and restores
    the canonical ordering (i < j for self-joins, lexicographic sort).  With
    plain ``Collection`` inputs it is the identity.

    NOTE: a ``bitmap=`` filter passed alongside prepared inputs must be built
    over the *prepared* order (see
    :func:`repro.core.engine.prepared_bitmap_filter`) — index spaces must
    agree or pruning is incorrect.
    """
    order_r = col.order if isinstance(col, PreparedCollection) else None
    self_join = col_s is None
    order_s = (order_r if self_join
               else col_s.order if isinstance(col_s, PreparedCollection)
               else None)
    if order_r is None and order_s is None:
        return lambda pairs: pairs

    def remap(pairs: np.ndarray) -> np.ndarray:
        if len(pairs) == 0:
            return pairs
        gi = order_r[pairs[:, 0]] if order_r is not None else pairs[:, 0]
        gj = order_s[pairs[:, 1]] if order_s is not None else pairs[:, 1]
        if self_join:
            out = np.stack([np.minimum(gi, gj), np.maximum(gi, gj)], axis=1)
        else:
            out = np.stack([gi, gj], axis=1)
        return out[np.lexsort((out[:, 1], out[:, 0]))].astype(np.int64)

    return remap


# ---------------------------------------------------------------------------
# AllPairs [3]: prefix filter (filter_1) + length filter (filter_2)
# ---------------------------------------------------------------------------

def _rs_probe_candidates(index, col_r: Collection, col_s: Collection, s: int,
                         sim: str, tau: float, positional: bool) -> set:
    """Candidate R ids for probe set ``s`` (shared prefix token + length
    window; optional positional filter at the first match)."""
    ls = int(col_s.lengths[s])
    p = _prefix_len(sim, tau, ls)
    lo, hi = _int_window(sim, tau, ls)
    seen: set[int] = set()
    for pos in range(p):
        for r, rpos in index[int(col_s.tokens[s, pos])]:
            lr = int(col_r.lengths[r])
            if lr > hi:
                break  # index lists are length-sorted: later r only longer
            if lr < lo:
                continue
            if r in seen:
                continue
            if positional:
                ub = bounds.positional_upper_bound(lr, ls, rpos, pos)
                need = bounds.equivalent_overlap(sim, tau, lr, ls)
                if ub < need:
                    continue
            seen.add(r)
    return seen


def _allpairs_like_rs(col_r: Collection, col_s: Collection, sim: str,
                      tau: float, bitmap: Optional[BitmapFilter],
                      stats: AlgoStats, positional: bool) -> np.ndarray:
    """Shared R×S driver for AllPairs (positional=False) / PPJoin (True)."""
    index = _build_prefix_index(col_r, sim, tau)
    results: List[Tuple[int, int]] = []
    for s in range(col_s.num_sets):
        seen = _rs_probe_candidates(index, col_r, col_s, s, sim, tau, positional)
        cands = np.fromiter(seen, dtype=np.int64, count=len(seen))
        stats.candidates += len(cands)
        if bitmap is not None and len(cands):
            pruned = bitmap.prune_mask(s, cands)  # filter_3 (probe side = S)
            stats.bitmap_pruned += int(pruned.sum())
            cands = cands[~pruned]
        for r in cands:
            if _verify_pair_rs(col_r, col_s, int(r), s, sim, tau, stats):
                results.append((int(r), s))
    stats.results = len(results)
    return _pack_pairs_rs(results)


def allpairs(col: Collection, col_s=None, sim: str = JACCARD, tau: float = 0.8,
             bitmap: Optional[BitmapFilter] = None,
             stats: Optional[AlgoStats] = None) -> np.ndarray:
    col_s, sim, tau = split_join_args(col_s, sim, tau)
    stats = stats if stats is not None else AlgoStats()
    remap = _prepared_remapper(col, col_s)
    if col_s is not None:
        return remap(_allpairs_like_rs(col, col_s, sim, tau, bitmap, stats,
                                       positional=False))
    index = _build_prefix_index(col, sim, tau)
    lengths = col.lengths
    results: List[Tuple[int, int]] = []
    for r in range(col.num_sets):
        lr = int(lengths[r])
        p = _prefix_len(sim, tau, lr)
        lo, _ = _int_window(sim, tau, lr)
        seen: set[int] = set()
        for pos in range(p):
            for s, _spos in index[int(col.tokens[r, pos])]:
                if s >= r:
                    break  # index lists are id-sorted; only s < r probes r's index
                if lengths[s] < lo:  # length filter (lists sorted by length)
                    continue
                seen.add(s)
        cands = np.fromiter(seen, dtype=np.int64, count=len(seen))
        stats.candidates += len(cands)
        if bitmap is not None and len(cands):
            pruned = bitmap.prune_mask(r, cands)  # filter_3
            stats.bitmap_pruned += int(pruned.sum())
            cands = cands[~pruned]
        for s in cands:
            if _verify_pair(col, r, int(s), sim, tau, stats):
                results.append((int(s), r))
    stats.results = len(results)
    return remap(_pack_pairs(results))


# ---------------------------------------------------------------------------
# PPJoin [25]: AllPairs + positional filter in candidate generation
# ---------------------------------------------------------------------------

def ppjoin(col: Collection, col_s=None, sim: str = JACCARD, tau: float = 0.8,
           bitmap: Optional[BitmapFilter] = None,
           stats: Optional[AlgoStats] = None) -> np.ndarray:
    col_s, sim, tau = split_join_args(col_s, sim, tau)
    stats = stats if stats is not None else AlgoStats()
    remap = _prepared_remapper(col, col_s)
    if col_s is not None:
        return remap(_allpairs_like_rs(col, col_s, sim, tau, bitmap, stats,
                                       positional=True))
    index = _build_prefix_index(col, sim, tau)
    lengths = col.lengths
    results: List[Tuple[int, int]] = []
    for r in range(col.num_sets):
        lr = int(lengths[r])
        p = _prefix_len(sim, tau, lr)
        lo, _ = _int_window(sim, tau, lr)
        seen: set[int] = set()
        for pos in range(p):
            for s, spos in index[int(col.tokens[r, pos])]:
                if s >= r:
                    break
                ls = int(lengths[s])
                if ls < lo:
                    continue
                if s in seen:
                    continue
                # Positional filter (filter_2): bound from first match position.
                ub = bounds.positional_upper_bound(lr, ls, pos, spos)
                need = bounds.equivalent_overlap(sim, tau, lr, ls)
                if ub < need:
                    continue
                seen.add(s)
        cands = np.fromiter(seen, dtype=np.int64, count=len(seen))
        stats.candidates += len(cands)
        if bitmap is not None and len(cands):
            pruned = bitmap.prune_mask(r, cands)  # filter_3
            stats.bitmap_pruned += int(pruned.sum())
            cands = cands[~pruned]
        for s in cands:
            if _verify_pair(col, r, int(s), sim, tau, stats):
                results.append((int(s), r))
    stats.results = len(results)
    return remap(_pack_pairs(results))


# ---------------------------------------------------------------------------
# GroupJoin [4]: PPJoin filters over groups of identical (size, prefix)
# ---------------------------------------------------------------------------

def _group_by_size_prefix(col: Collection, sim: str, tau: float):
    """Group sets sharing (size, prefix tokens); returns (members, reps)."""
    group_of: Dict[Tuple, int] = {}
    members: List[List[int]] = []
    rep: List[int] = []
    for i in range(col.num_sets):
        n = int(col.lengths[i])
        p = _prefix_len(sim, tau, n)
        key = (n, tuple(int(t) for t in col.tokens[i, :p]))
        g = group_of.get(key)
        if g is None:
            group_of[key] = len(members)
            members.append([i])
            rep.append(i)
        else:
            members[g].append(i)
    return members, rep


def _groupjoin_rs(col_r: Collection, col_s: Collection, sim: str, tau: float,
                  bitmap: Optional[BitmapFilter], stats: AlgoStats) -> np.ndarray:
    """R×S GroupJoin: R grouped by (size, prefix), probed with each S set.

    Filters run once per (probe, R-group); the bitmap filter applies to the
    *expanded* member pairs (paper Section 4.1).  No within-group stage — those
    pairs are R–R, which a two-collection join never reports.
    """
    members, rep = _group_by_size_prefix(col_r, sim, tau)
    grows = [col_r.row(rep[g]) for g in range(len(members))]
    glen = np.array([len(r) for r in grows], dtype=np.int64)

    index: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
    for g, row in enumerate(grows):
        p = _prefix_len(sim, tau, len(row))
        for pos in range(p):
            index[int(row[pos])].append((g, pos))

    results: List[Tuple[int, int]] = []
    for s in range(col_s.num_sets):
        ls = int(col_s.lengths[s])
        p = _prefix_len(sim, tau, ls)
        lo, hi = _int_window(sim, tau, ls)
        seen: set[int] = set()
        for pos in range(p):
            for g, gpos in index[int(col_s.tokens[s, pos])]:
                lg = int(glen[g])
                if lg > hi:
                    break  # groups are length-sorted like their members
                if lg < lo or g in seen:
                    continue
                ub = bounds.positional_upper_bound(lg, ls, gpos, pos)
                need = bounds.equivalent_overlap(sim, tau, lg, ls)
                if ub < need:
                    continue
                seen.add(g)
        for g in seen:
            cands = np.asarray(members[g], dtype=np.int64)
            stats.candidates += len(cands)
            if bitmap is not None:
                pruned = bitmap.prune_mask(s, cands)
                stats.bitmap_pruned += int(pruned.sum())
                cands = cands[~pruned]
            for r in cands:
                if _verify_pair_rs(col_r, col_s, int(r), s, sim, tau, stats):
                    results.append((int(r), s))
    stats.results = len(results)
    return _pack_pairs_rs(results)


def groupjoin(col: Collection, col_s=None, sim: str = JACCARD, tau: float = 0.8,
              bitmap: Optional[BitmapFilter] = None,
              stats: Optional[AlgoStats] = None) -> np.ndarray:
    col_s, sim, tau = split_join_args(col_s, sim, tau)
    stats = stats if stats is not None else AlgoStats()
    remap = _prepared_remapper(col, col_s)
    if col_s is not None:
        return remap(_groupjoin_rs(col, col_s, sim, tau, bitmap, stats))
    # Group sets sharing (size, prefix tokens). Filters run once per group
    # representative; the verification stage expands groups to members.
    members, rep = _group_by_size_prefix(col, sim, tau)
    gcol_rows = [col.row(rep[g]) for g in range(len(members))]
    glen = np.array([len(r) for r in gcol_rows], dtype=np.int64)

    index: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
    for g, row in enumerate(gcol_rows):
        p = _prefix_len(sim, tau, len(row))
        for pos in range(p):
            index[int(row[pos])].append((g, pos))

    results: List[Tuple[int, int]] = []
    for g, row in enumerate(gcol_rows):
        lg = int(glen[g])
        p = _prefix_len(sim, tau, lg)
        lo, _ = _int_window(sim, tau, lg)
        seen: set[int] = set()
        for pos in range(p):
            for h, hpos in index[int(row[pos])]:
                if h >= g:
                    break
                lh = int(glen[h])
                if lh < lo:
                    continue
                if h in seen:
                    continue
                ub = bounds.positional_upper_bound(lg, lh, pos, hpos)
                need = bounds.equivalent_overlap(sim, tau, lg, lh)
                if ub < need:
                    continue
                seen.add(h)
        # Expand groups: candidate pairs are member cross-products; the
        # bitmap filter (filter_3) applies to *individual* expanded pairs
        # (paper Section 4.1). Batched per left member.
        for h in seen:
            partner = np.asarray(members[h], dtype=np.int64)
            for r in members[g]:
                stats.candidates += len(partner)
                cands = partner
                if bitmap is not None:
                    pruned = bitmap.prune_mask(r, cands)
                    stats.bitmap_pruned += int(pruned.sum())
                    cands = cands[~pruned]
                for s in cands:
                    if _verify_pair(col, r, int(s), sim, tau, stats):
                        results.append(_ordered(r, int(s)))
        # Within-group pairs: identical prefixes and sizes — still must verify.
        gm = members[g]
        for a in range(len(gm)):
            partner = np.asarray(gm[a + 1:], dtype=np.int64)
            if len(partner) == 0:
                continue
            stats.candidates += len(partner)
            cands = partner
            if bitmap is not None:
                pruned = bitmap.prune_mask(gm[a], cands)
                stats.bitmap_pruned += int(pruned.sum())
                cands = cands[~pruned]
            for s in cands:
                if _verify_pair(col, gm[a], int(s), sim, tau, stats):
                    results.append(_ordered(gm[a], int(s)))
    stats.results = len(results)
    return remap(_pack_pairs(results))


# ---------------------------------------------------------------------------
# AdaptJoin [23]: variable-length prefix schema
# ---------------------------------------------------------------------------

def _adapt_select_ell(match_count: Dict[int, int], probe_cost: int,
                      max_ell: int, sim: str, tau: float, n: int):
    """Adaptive ℓ selection: take the smallest ℓ whose candidate count stops
    paying for another index pass (monotone counts make this the standard
    [23] heuristic).  Returns (ell, candidate ids at that level).

    The ℓ-prefix theorem guarantees ≥ ℓ shared prefix tokens only when the
    required overlap itself is ≥ ℓ, so ℓ is capped at the probe's minimum
    equivalent overlap (= n - prefix_length(n) + 1) — without the cap, small
    sets with o_req < ℓ lose true pairs.
    """
    o_min = max(n - _prefix_len(sim, tau, n) + 1, 1)
    max_ell = min(max_ell, o_min)
    cand_at = []
    for l in range(1, max_ell + 1):
        cand_at.append([s for s, c in match_count.items() if c >= l])
    ell = 1
    for l in range(1, max_ell):
        saving = len(cand_at[l - 1]) - len(cand_at[l])
        if saving > probe_cost:
            ell = l + 1
        else:
            break
    return ell, cand_at[ell - 1]


def _adaptjoin_rs(col_r: Collection, col_s: Collection, sim: str, tau: float,
                  bitmap: Optional[BitmapFilter], stats: AlgoStats,
                  max_ell: int) -> np.ndarray:
    """R×S AdaptJoin: the ℓ-prefix index over R, probed with every S set."""
    index = _build_prefix_index(col_r, sim, tau, ell=max_ell)
    results: List[Tuple[int, int]] = []
    for s in range(col_s.num_sets):
        ls = int(col_s.lengths[s])
        lo, hi = _int_window(sim, tau, ls)
        match_count: Dict[int, int] = defaultdict(int)
        plen = _prefix_len(sim, tau, ls, max_ell)
        for pos in range(plen):
            for r, _rpos in index[int(col_s.tokens[s, pos])]:
                lr = int(col_r.lengths[r])
                if lr > hi:
                    break  # length-sorted index lists
                if lr < lo:
                    continue
                match_count[r] += 1
        ell, cand_ids = _adapt_select_ell(match_count, ls, max_ell, sim, tau, ls)
        cands = np.asarray(sorted(cand_ids), dtype=np.int64)
        stats.candidates += len(cands)
        if bitmap is not None and len(cands) and ell == 1:
            pruned = bitmap.prune_mask(s, cands)  # filter_2 @ 1-prefix pass
            stats.bitmap_pruned += int(pruned.sum())
            cands = cands[~pruned]
        for r in cands:
            if _verify_pair_rs(col_r, col_s, int(r), s, sim, tau, stats):
                results.append((int(r), s))
    stats.results = len(results)
    return _pack_pairs_rs(results)


def adaptjoin(col: Collection, col_s=None, sim: str = JACCARD, tau: float = 0.8,
              bitmap: Optional[BitmapFilter] = None,
              stats: Optional[AlgoStats] = None,
              max_ell: int = 3) -> np.ndarray:
    """AdaptJoin with the ℓ-prefix schema and a candidate-count cost model.

    For each probe the algorithm extends the prefix (ℓ = 1, 2, ...) while the
    estimated saving (candidates dropped x verify cost) exceeds the extra
    index-probe cost — the simplified cost model of [23].  Candidates must
    share >= ℓ prefix tokens.  The Bitmap Filter runs at candidate generation
    (filter_2) during the ℓ=1 iteration, per paper Section 4.1.

    R×S form: the ℓ-prefix index is built over R and probed with every S set.
    """
    col_s, sim, tau = split_join_args(col_s, sim, tau)
    stats = stats if stats is not None else AlgoStats()
    remap = _prepared_remapper(col, col_s)
    if col_s is not None:
        return remap(_adaptjoin_rs(col, col_s, sim, tau, bitmap, stats, max_ell))
    index = _build_prefix_index(col, sim, tau, ell=max_ell)
    lengths = col.lengths
    results: List[Tuple[int, int]] = []
    for r in range(col.num_sets):
        lr = int(lengths[r])
        lo, _ = _int_window(sim, tau, lr)
        # Count prefix-token matches per probed set for each ℓ level.
        match_count: Dict[int, int] = defaultdict(int)
        plen = [_prefix_len(sim, tau, lr, l) for l in range(1, max_ell + 1)]
        # Probe the widest prefix once; candidates at level ℓ are those with
        # match_count >= ℓ inside the level's prefix window.
        for pos in range(plen[-1]):
            for s, spos in index[int(col.tokens[r, pos])]:
                if s >= r:
                    break
                ls = int(lengths[s])
                if ls < lo:
                    continue
                # s's own prefix at level ℓ shrinks too; the index stores
                # max_ell prefixes, so re-check the position lazily below.
                match_count[s] += 1
        ell, cand_ids = _adapt_select_ell(match_count, lr, max_ell, sim, tau, lr)
        cands = np.asarray(sorted(cand_ids), dtype=np.int64)
        stats.candidates += len(cands)
        if bitmap is not None and len(cands) and ell == 1:
            pruned = bitmap.prune_mask(r, cands)  # filter_2 @ 1-prefix pass
            stats.bitmap_pruned += int(pruned.sum())
            cands = cands[~pruned]
        for s in cands:
            if _verify_pair(col, r, int(s), sim, tau, stats):
                results.append((int(s), r))
    stats.results = len(results)
    return remap(_pack_pairs(results))


ALGORITHMS: Dict[str, Callable] = {
    "allpairs": allpairs,
    "ppjoin": ppjoin,
    "groupjoin": groupjoin,
    "adaptjoin": adaptjoin,
}


def _ordered(r: int, s: int) -> Tuple[int, int]:
    return (s, r) if s < r else (r, s)


def _pack_pairs(results: List[Tuple[int, int]]) -> np.ndarray:
    if not results:
        return np.zeros((0, 2), dtype=np.int64)
    arr = np.asarray(sorted(set(_ordered(a, b) for a, b in results)), dtype=np.int64)
    return arr
