"""Padded, device-friendly representation of a collection of token sets.

A collection ``R = {r_1, ..., r_N}`` of sets of integer tokens is stored as a
dense, padded ``tokens`` matrix plus a ``lengths`` vector.  Tokens inside each
row are sorted ascending; padding uses ``PAD_TOKEN`` (int32 max) so that sorted
rows keep padding at the end, which makes merge/searchsorted-based exact
verification branch-free.

The paper's preprocessing (Section 5) is reproduced by :func:`preprocess`:
tokens are re-labelled by ascending global frequency (which maximises prefix
filter selectivity) and sets are ordered by size, ties broken lexicographically.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence

import numpy as np

from repro.core.constants import PAD_TOKEN


@dataclasses.dataclass
class Collection:
    """A padded collection of token sets.

    Attributes:
      tokens: int32[N, L] — row-sorted tokens, padded with ``PAD_TOKEN``.
      lengths: int32[N] — true set sizes.
    """

    tokens: np.ndarray
    lengths: np.ndarray

    @property
    def num_sets(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def max_len(self) -> int:
        return int(self.tokens.shape[1])

    def __len__(self) -> int:
        return self.num_sets

    def row(self, i: int) -> np.ndarray:
        """Return the (unpadded) sorted token array of set ``i``."""
        return self.tokens[i, : self.lengths[i]]

    def as_lists(self) -> List[List[int]]:
        return [list(self.row(i)) for i in range(self.num_sets)]


def from_lists(sets: Sequence[Iterable[int]], pad_to: int | None = None) -> Collection:
    """Build a :class:`Collection` from an iterable of token iterables.

    Duplicate tokens within one set are removed (sets, not bags).
    """
    uniq = [np.unique(np.asarray(list(s), dtype=np.int64)).astype(np.int64) for s in sets]
    lengths = np.array([len(u) for u in uniq], dtype=np.int32)
    max_len = int(lengths.max()) if len(lengths) else 0
    if pad_to is not None:
        if pad_to < max_len:
            raise ValueError(f"pad_to={pad_to} < max set length {max_len}")
        max_len = pad_to
    tokens = np.full((len(uniq), max(max_len, 1)), PAD_TOKEN, dtype=np.int32)
    for i, u in enumerate(uniq):
        if np.any(u >= PAD_TOKEN) or np.any(u < 0):
            raise ValueError("tokens must be in [0, PAD_TOKEN)")
        tokens[i, : len(u)] = u.astype(np.int32)
    return Collection(tokens=tokens, lengths=lengths)


def split_join_args(col_s, sim, tau):
    """Support both ``(col, sim, tau)`` and ``(col_r, col_s, sim, tau)``.

    Every join driver historically took ``sim`` as its second positional
    argument; when the second argument is a similarity name instead of a
    :class:`Collection`, the remaining positionals shift right and the call
    is a self-join.
    """
    if isinstance(col_s, str):
        if not isinstance(tau, (int, float)) or isinstance(tau, bool):
            # A displaced object (e.g. a BitmapFilter passed positionally
            # after (col, sim, tau)) would otherwise be dropped silently.
            raise TypeError(
                "extra positional argument after (col, sim, tau); pass "
                "bitmap=/stats= by keyword")
        if isinstance(sim, (int, float)) and not isinstance(sim, bool):
            tau = float(sim)
        sim = col_s
        col_s = None
    return col_s, sim, tau


def _frequency_lut(flat: np.ndarray) -> dict:
    """token -> rank by (frequency, token); deterministic relabelling."""
    uniq, counts = np.unique(flat, return_counts=True)
    order = np.lexsort((uniq, counts))
    rank = np.empty(len(uniq), dtype=np.int64)
    rank[order] = np.arange(len(uniq))
    return dict(zip(uniq.tolist(), rank.tolist()))


def _relabel_and_sort(col: Collection, lut: dict) -> Collection:
    relabeled: List[List[int]] = []
    for i in range(col.num_sets):
        relabeled.append(sorted(lut[int(t)] for t in col.row(i)))
    # Sort sets by (size, lexicographic token ids).
    relabeled.sort(key=lambda r: (len(r), tuple(r)))
    return from_lists(relabeled)


def preprocess(col: Collection) -> Collection:
    """Paper Section 5 preprocessing.

    1. Re-label tokens by ascending global frequency (rarest token gets the
       smallest id). This is the canonical ordering that makes prefix filters
       most selective, and what the reference implementation of [13] does.
    2. Sort sets by size; ties broken lexicographically by token ids.
    """
    return _relabel_and_sort(col, _frequency_lut(col.tokens[col.tokens != PAD_TOKEN]))


def preprocess_rs(col_r: Collection, col_s: Collection) -> tuple[Collection, Collection]:
    """Section 5 preprocessing for a two-collection R×S join.

    Token frequencies are counted over the union of *both* collections so the
    relabelled ids form one shared total order — prefix-filter correctness
    and selectivity depend on R and S agreeing on it (relabelling each side
    independently would map the same token to different ids).  Each collection
    is then sorted by size as in :func:`preprocess`.
    """
    flat = np.concatenate([col_r.tokens[col_r.tokens != PAD_TOKEN],
                           col_s.tokens[col_s.tokens != PAD_TOKEN]])
    lut = _frequency_lut(flat)
    return _relabel_and_sort(col_r, lut), _relabel_and_sort(col_s, lut)


def pad_collection(col: Collection, num_sets: int, max_len: int | None = None) -> Collection:
    """Pad a collection with empty sets up to ``num_sets`` (for block tiling)."""
    max_len = max_len or col.max_len
    if num_sets < col.num_sets:
        raise ValueError("cannot shrink collection")
    tokens = np.full((num_sets, max_len), PAD_TOKEN, dtype=np.int32)
    tokens[: col.num_sets, : col.max_len] = col.tokens
    lengths = np.zeros((num_sets,), dtype=np.int32)
    lengths[: col.num_sets] = col.lengths
    return Collection(tokens=tokens, lengths=lengths)
