"""Exact set-similarity join drivers.

Three tiers, mirroring the paper's structure:

* :func:`naive_join` — Algorithm 1, the O(|R|·|S|) oracle (tests/small inputs).
* :func:`blocked_bitmap_join` — the TPU adaptation of the paper's GPU
  Algorithm 8: length-sorted collection, block-level length-filter early-out,
  fused bitmap-filter tiles (Pallas), dense-mask compaction, batched exact
  verification on device. Host drives the block loop (like the GPU host code
  drives kernel launches).
* :func:`ring_join_sharded` — multi-device version: R is sharded over the
  mesh's batch axes, S blocks circulate via ``collective_permute``; each ring
  step runs the same fused filter + verification locally. Used by the
  dedup pipeline and by the dry-run.

All joins return *exactly* the same pair set as the oracle (property-tested);
the bitmap filter only ever removes pairs that verification would reject.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core import bounds, expected, verify
from repro.core.collection import Collection
from repro.core.constants import BITMAP_COMBINED, JACCARD
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# Oracle
# ---------------------------------------------------------------------------

def naive_join(col: Collection, sim: str, tau: float) -> np.ndarray:
    """Algorithm 1 (self-join): all verified pairs as int64[K, 2] (i < j)."""
    tokens = jnp.asarray(col.tokens)
    lengths = jnp.asarray(col.lengths)
    n = col.num_sets
    o = _overlap_matrix(tokens)
    need = bounds.equivalent_overlap(sim, tau, np.asarray(lengths)[:, None],
                                     np.asarray(lengths)[None, :])
    simmat = np.asarray(o) >= need
    # Empty sets (padding) are never similar to anything — the vacuous
    # 0 >= 0 case for normalised similarities is excluded, matching the
    # paper's definition over non-empty sets.
    nz = np.asarray(lengths) > 0
    simmat &= nz[:, None] & nz[None, :]
    iu = np.triu_indices(n, k=1)
    mask = simmat[iu]
    return np.stack([iu[0][mask], iu[1][mask]], axis=1).astype(np.int64)


@jax.jit
def _overlap_matrix(tokens: jnp.ndarray) -> jnp.ndarray:
    def row_vs_all(row):
        return jax.vmap(lambda s: verify._row_overlap(row, s))(tokens)

    return jax.vmap(row_vs_all)(tokens)


# ---------------------------------------------------------------------------
# Blocked device join (Algorithm 8, TPU-native)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JoinStats:
    """Observability counters (paper Tables 9-10 are derived from these)."""

    total_pairs: int = 0          # pairs inside length-filter windows
    blocks_total: int = 0
    blocks_skipped: int = 0       # block pairs pruned by the length filter
    candidates: int = 0           # pairs surviving the bitmap filter
    verified_true: int = 0        # final result size

    @property
    def filter_ratio(self) -> float:
        """Fraction of length-surviving pairs pruned by the bitmap filter."""
        if self.total_pairs == 0:
            return 0.0
        return 1.0 - self.candidates / self.total_pairs

    @property
    def precision(self) -> float:
        """true positives / unfiltered (Section 5.1.3)."""
        if self.candidates == 0:
            return 1.0
        return self.verified_true / self.candidates


def _length_sorted(col: Collection) -> tuple[Collection, np.ndarray]:
    order = np.argsort(col.lengths, kind="stable")
    return Collection(tokens=col.tokens[order], lengths=col.lengths[order]), order


def blocked_bitmap_join(
    col: Collection,
    sim: str = JACCARD,
    tau: float = 0.8,
    *,
    b: int = 128,
    method: str = BITMAP_COMBINED,
    block: int = 4096,
    impl: str = "auto",
    use_cutoff: bool = True,
    use_bitmap: bool = True,
    return_stats: bool = False,
):
    """Exact self-join; returns int64[K, 2] pairs in original indices.

    The driver walks upper-triangular block pairs of the length-sorted
    collection. Because blocks are length-contiguous, the Table 2 length
    window prunes whole block pairs (the TPU analogue of the paper's sorted
    inverted-list early termination). Surviving tiles run the fused bitmap
    kernel; candidates are compacted on host and exactly verified on device.
    """
    scol, order = _length_sorted(col)
    n = scol.num_sets
    tokens = jnp.asarray(scol.tokens)
    lengths = jnp.asarray(scol.lengths)

    if method == BITMAP_COMBINED:
        chosen = bm.choose_method(tau, b)
    else:
        chosen = method
    cutoff = expected.cutoff_point(chosen, b, float(tau)) if use_cutoff else 1 << 30
    words = bm.generate_bitmaps(tokens, lengths, b, method=chosen)

    np_len = np.asarray(scol.lengths)
    stats = JoinStats()
    pairs_out: list[np.ndarray] = []
    nb = math.ceil(n / block)

    for bi in range(nb):
        r0, r1 = bi * block, min((bi + 1) * block, n)
        max_lr = int(np_len[r1 - 1]) if r1 > r0 else 0
        _, hi = bounds.length_bounds(sim, tau, max(int(np_len[r0]), 1))
        for bj in range(bi, nb):
            s0, s1 = bj * block, min((bj + 1) * block, n)
            stats.blocks_total += 1
            min_ls = int(np_len[s0])
            # Block-level length filter: smallest |s| in block j vs the
            # largest admissible |s| for the *largest* r in block i — blocks
            # are length-sorted, so if this fails every later bj fails too.
            _, hi_r1 = bounds.length_bounds(sim, tau, max(max_lr, 1))
            if min_ls > hi_r1:
                stats.blocks_skipped += nb - bj
                break
            in_window = _window_pair_count(
                np_len[r0:r1], np_len[s0:s1], sim, tau, bi == bj)
            stats.total_pairs += int(in_window)
            if use_bitmap:
                cand = kops.candidate_matrix(
                    words[r0:r1], words[s0:s1],
                    lengths[r0:r1], lengths[s0:s1],
                    sim=sim, tau=float(tau), self_join=False,
                    cutoff=int(cutoff), impl=impl)
                cand = np.asarray(cand)
            else:
                cand = _window_pair_mask(np_len[r0:r1], np_len[s0:s1], sim, tau)
            if bi == bj:
                cand = np.triu(cand, k=1)
            ii, jj = np.nonzero(cand)
            if len(ii) == 0:
                continue
            stats.candidates += len(ii)
            gi = jnp.asarray(ii + r0)
            gj = jnp.asarray(jj + s0)
            ok = np.asarray(verify.verify_pairs(tokens, lengths, gi, gj, sim, float(tau)))
            if ok.any():
                stats.verified_true += int(ok.sum())
                pairs_out.append(
                    np.stack([order[np.asarray(gi)[ok]], order[np.asarray(gj)[ok]]], axis=1))

    if pairs_out:
        pairs = np.concatenate(pairs_out, axis=0)
        lo = np.minimum(pairs[:, 0], pairs[:, 1])
        hi_ = np.maximum(pairs[:, 0], pairs[:, 1])
        pairs = np.stack([lo, hi_], axis=1)
        pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
    else:
        pairs = np.zeros((0, 2), dtype=np.int64)
    if return_stats:
        return pairs, stats
    return pairs


def _window_pair_mask(len_r: np.ndarray, len_s: np.ndarray, sim: str, tau: float) -> np.ndarray:
    lo, hi = bounds.length_bounds(sim, tau, len_r.astype(np.float64)[:, None])
    ls = len_s.astype(np.float64)[None, :]
    mask = (ls >= lo) & (ls <= hi) & (len_r[:, None] > 0) & (len_s[None, :] > 0)
    return mask


def _window_pair_count(len_r, len_s, sim, tau, diagonal: bool) -> int:
    mask = _window_pair_mask(len_r, len_s, sim, tau)
    if diagonal:
        mask = np.triu(mask, k=1)
    return int(mask.sum())


# ---------------------------------------------------------------------------
# Distributed ring join (shard_map + collective_permute)
# ---------------------------------------------------------------------------

def ring_join_sharded(
    tokens: jnp.ndarray,
    lengths: jnp.ndarray,
    words: jnp.ndarray,
    *,
    mesh,
    axis: str | tuple[str, ...],
    sim: str,
    tau: float,
    cutoff: int = 1 << 30,
    impl: str = "ref",
    capacity_per_step: int | None = None,
):
    """Distributed exact self-join via a ring sweep.

    R is sharded over ``axis``; every ring step rotates the S shard (bitmaps +
    tokens + lengths) one hop with ``collective_permute`` while the local
    shard runs the fused bitmap filter + exact verification against the block
    it currently holds.  After ``n_dev`` steps every pair (i < j) has been
    examined exactly once.  The permuted operands of step k+1 are independent
    of step k's math, so XLA's latency-hiding scheduler can overlap the
    ICI transfer with the tile compute.

    Candidates are compacted into a fixed ``capacity_per_step`` buffer per
    device — the TPU analogue of Algorithm 8's 2048-entry thread-local lists;
    on overflow (counted and returned) the caller re-runs the affected step
    densely, preserving exactness.

    Returns ``(pairs, valid, counters)``:
      pairs: int32[n_dev * steps * cap, 2] global (i, j) ids (garbage where
        ``valid`` is False), sharded over ``axis``.
      valid: bool with matching leading dim — verified-similar slots.
      counters: int64[n_dev, 3] per-device (candidates, verified, overflow).
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    axis_name = axes if len(axes) > 1 else axes[0]
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))
    n = tokens.shape[0]
    if n % n_dev:
        raise ValueError(f"collection size {n} must divide over {n_dev} devices (pad first)")
    shard_n = n // n_dev
    cap = capacity_per_step or max(8 * shard_n, 128)

    spec = P(axes)

    def local(tok, length, word):
        my = jax.lax.axis_index(axis_name)
        gi = my * shard_n + jnp.arange(shard_n, dtype=jnp.int32)

        def step(carry, t):
            (s_tok, s_len, s_word), (cand_acc, ver_acc, ovf_acc) = carry
            s_dev = (my - t) % n_dev  # origin device of the S shard we hold
            gj = s_dev * shard_n + jnp.arange(shard_n, dtype=jnp.int32)
            cand = kops.candidate_matrix(
                word, s_word, length, s_len,
                sim=sim, tau=float(tau), self_join=False,
                cutoff=int(cutoff), impl=impl)
            cand &= gi[:, None] < gj[None, :]
            n_cand = jnp.sum(cand, dtype=jnp.int32)
            # Fixed-capacity compaction (Algorithm 8's local candidate list).
            ii, jj = jnp.nonzero(cand, size=cap, fill_value=0)
            slot_valid = jnp.arange(cap) < n_cand
            ok = verify.pairwise_overlap(tok[ii], s_tok[jj])
            need = _need(sim, tau, length[ii], s_len[jj])
            ok_mask = slot_valid & (ok >= need)
            out_pairs = jnp.stack([ii + my * shard_n,
                                   jj + s_dev * shard_n], axis=1).astype(jnp.int32)
            perm = [(d, (d + 1) % n_dev) for d in range(n_dev)]
            nxt = tuple(jax.lax.ppermute(x, axis_name, perm)
                        for x in (s_tok, s_len, s_word))
            accs = (cand_acc + n_cand.astype(jnp.int64),
                    ver_acc + jnp.sum(ok_mask, dtype=jnp.int64),
                    ovf_acc + (n_cand > cap).astype(jnp.int64))
            return (nxt, accs), (out_pairs, ok_mask)

        zero = jnp.int64(0)
        init = ((tok, length, word), (zero, zero, zero))
        (_, (cand, ver, ovf)), (pairs, valid) = jax.lax.scan(
            step, init, jnp.arange(n_dev, dtype=jnp.int32))
        counters = jnp.stack([cand, ver, ovf])[None]  # (1, 3) per device
        return pairs.reshape(-1, 2), valid.reshape(-1), counters

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=(P(axes), P(axes), P(axes)),
        check_rep=False,
    )
    return fn(tokens, lengths, words)


def _need(sim: str, tau: float, lr, ls):
    lr = lr.astype(jnp.float32)
    ls = ls.astype(jnp.float32)
    if sim == "overlap":
        return jnp.full_like(lr + ls, float(tau))
    if sim == "jaccard":
        return (tau / (1.0 + tau)) * (lr + ls)
    if sim == "cosine":
        return tau * jnp.sqrt(lr * ls)
    if sim == "dice":
        return (tau / 2.0) * (lr + ls)
    raise ValueError(sim)
