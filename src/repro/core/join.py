"""Exact set-similarity join drivers.

Three tiers, mirroring the paper's structure:

* :func:`naive_join` — Algorithm 1, the O(|R|·|S|) oracle (tests/small inputs).
* :func:`blocked_bitmap_join` — the TPU adaptation of the paper's GPU
  Algorithm 8: length-sorted collection, block-level length-filter early-out,
  fused bitmap-filter tiles (Pallas), dense-mask compaction, batched exact
  verification on device. Host drives the block loop (like the GPU host code
  drives kernel launches).
* :func:`ring_join_sharded` — multi-device version: R is sharded over the
  mesh's batch axes, S blocks circulate via ``collective_permute``; each ring
  step runs the same fused filter + verification locally. Used by the
  dedup pipeline and by the dry-run.

Every driver supports both the paper's general two-collection R×S join and
the optimized self-join special case.  Self-join is selected by omitting the
second collection: ``naive_join(col, sim, tau)`` (the seed calling convention
still works positionally); R×S by passing it: ``naive_join(col_r, col_s, sim,
tau)``.  Self-joins return pairs ``(i, j)`` with ``i < j``; R×S joins return
``(r_index, s_index)`` pairs over the two collections' original indices.

All joins return *exactly* the same pair set as the oracle (property-tested);
the bitmap filter only ever removes pairs that verification would reject.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core import bounds, expected, verify
from repro.core.collection import Collection, split_join_args
from repro.core.constants import BITMAP_COMBINED, JACCARD
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# Oracle
# ---------------------------------------------------------------------------

_normalize_rs_args = split_join_args


def naive_join(col_r: Collection, col_s: Collection | str | None = None,
               sim: str = JACCARD, tau: float = 0.8) -> np.ndarray:
    """Algorithm 1: all verified pairs as int64[K, 2].

    Self-join (``col_s`` omitted) returns pairs with i < j; R×S returns
    (r_index, s_index) over the full cross product.
    """
    col_s, sim, tau = _normalize_rs_args(col_s, sim, tau)
    self_join = col_s is None
    if self_join:
        col_s = col_r
    o = _overlap_matrix(jnp.asarray(col_r.tokens), jnp.asarray(col_s.tokens))
    len_r = np.asarray(col_r.lengths)
    len_s = np.asarray(col_s.lengths)
    need = bounds.equivalent_overlap(sim, tau, len_r[:, None], len_s[None, :])
    simmat = np.asarray(o) >= need
    # Empty sets (padding) are never similar to anything — the vacuous
    # 0 >= 0 case for normalised similarities is excluded, matching the
    # paper's definition over non-empty sets.
    simmat &= (len_r > 0)[:, None] & (len_s > 0)[None, :]
    if self_join:
        iu = np.triu_indices(col_r.num_sets, k=1)
        mask = simmat[iu]
        return np.stack([iu[0][mask], iu[1][mask]], axis=1).astype(np.int64)
    ii, jj = np.nonzero(simmat)
    return np.stack([ii, jj], axis=1).astype(np.int64)


@jax.jit
def _overlap_matrix(tokens_r: jnp.ndarray, tokens_s: jnp.ndarray) -> jnp.ndarray:
    def row_vs_all(row):
        return jax.vmap(lambda s: verify._row_overlap(row, s))(tokens_s)

    return jax.vmap(row_vs_all)(tokens_r)


# ---------------------------------------------------------------------------
# Blocked device join (Algorithm 8, TPU-native)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JoinStats:
    """Observability counters (paper Tables 9-10 are derived from these)."""

    total_pairs: int = 0          # pairs inside length-filter windows
    blocks_total: int = 0
    blocks_skipped: int = 0       # block pairs pruned by the length filter
    candidates: int = 0           # pairs surviving the bitmap filter
    verified_true: int = 0        # final result size

    @property
    def filter_ratio(self) -> float:
        """Fraction of length-surviving pairs pruned by the bitmap filter."""
        if self.total_pairs == 0:
            return 0.0
        return 1.0 - self.candidates / self.total_pairs

    @property
    def precision(self) -> float:
        """true positives / unfiltered (Section 5.1.3)."""
        if self.candidates == 0:
            return 1.0
        return self.verified_true / self.candidates


def _length_sorted(col: Collection) -> tuple[Collection, np.ndarray]:
    order = np.argsort(col.lengths, kind="stable")
    return Collection(tokens=col.tokens[order], lengths=col.lengths[order]), order


def blocked_bitmap_join(
    col_r: Collection,
    col_s: Collection | str | None = None,
    sim: str = JACCARD,
    tau: float = 0.8,
    *,
    b: int = 128,
    method: str = BITMAP_COMBINED,
    block: int = 4096,
    impl: str = "auto",
    use_cutoff: bool = True,
    use_bitmap: bool = True,
    return_stats: bool = False,
):
    """Exact join; returns int64[K, 2] pairs in original indices.

    The driver walks block pairs of the length-sorted collections — the full
    R×S grid for two collections, the upper triangle for a self-join. Because
    blocks are length-contiguous, the Table 2 length window prunes whole block
    pairs in both directions (the TPU analogue of the paper's sorted
    inverted-list early termination). Surviving tiles run the fused bitmap
    kernel; bitmap candidates are intersected with the per-pair length-window
    mask (so ``JoinStats.candidates <= total_pairs`` always), compacted on
    host and exactly verified on device.
    """
    col_s, sim, tau = _normalize_rs_args(col_s, sim, tau)
    self_join = col_s is None
    scol_r, order_r = _length_sorted(col_r)
    if self_join:
        scol_s, order_s = scol_r, order_r
    else:
        scol_s, order_s = _length_sorted(col_s)
    nr, ns = scol_r.num_sets, scol_s.num_sets
    tokens_r = jnp.asarray(scol_r.tokens)
    lengths_r = jnp.asarray(scol_r.lengths)
    tokens_s = jnp.asarray(scol_s.tokens)
    lengths_s = jnp.asarray(scol_s.lengths)

    if method == BITMAP_COMBINED:
        chosen = bm.choose_method(tau, b)
    else:
        chosen = method
    cutoff = expected.cutoff_point(chosen, b, float(tau)) if use_cutoff else 1 << 30
    words_r = bm.generate_bitmaps(tokens_r, lengths_r, b, method=chosen)
    words_s = words_r if self_join else bm.generate_bitmaps(
        tokens_s, lengths_s, b, method=chosen)

    np_len_r = np.asarray(scol_r.lengths)
    np_len_s = np.asarray(scol_s.lengths)
    stats = JoinStats()
    pairs_out: list[np.ndarray] = []
    nb_r = math.ceil(nr / block)
    nb_s = math.ceil(ns / block)

    for bi in range(nb_r):
        r0, r1 = bi * block, min((bi + 1) * block, nr)
        min_lr = int(np_len_r[r0])
        max_lr = int(np_len_r[r1 - 1])
        # Admissible |s| window for the whole R block: the length bounds are
        # nondecreasing in |r|, so the block-wide window is
        # [lo(min |r|), hi(max |r|)].
        lo_r0, _ = bounds.length_bounds(sim, tau, max(min_lr, 1))
        _, hi_r1 = bounds.length_bounds(sim, tau, max(max_lr, 1))
        for bj in range(bi if self_join else 0, nb_s):
            s0, s1 = bj * block, min((bj + 1) * block, ns)
            stats.blocks_total += 1
            min_ls = int(np_len_s[s0])
            max_ls = int(np_len_s[s1 - 1])
            # Blocks are length-sorted: if the smallest |s| already exceeds
            # the window every later bj fails too (terminate the row) ...
            if min_ls > hi_r1:
                stats.blocks_total += nb_s - bj - 1
                stats.blocks_skipped += nb_s - bj
                break
            # ... and if the largest |s| is still below it, only this bj
            # fails (later blocks hold longer sets).
            if max_ls < lo_r0:
                stats.blocks_skipped += 1
                continue
            win = _window_pair_mask(np_len_r[r0:r1], np_len_s[s0:s1], sim, tau)
            if self_join and bi == bj:
                win = np.triu(win, k=1)
            stats.total_pairs += int(win.sum())
            if use_bitmap:
                cand = kops.candidate_matrix(
                    words_r[r0:r1], words_s[s0:s1],
                    lengths_r[r0:r1], lengths_s[s0:s1],
                    sim=sim, tau=float(tau), self_join=False,
                    cutoff=int(cutoff), impl=impl)
                # The fused kernel does not apply the length filter; without
                # this intersection `candidates` could exceed `total_pairs`
                # and filter_ratio could go negative.
                cand = np.asarray(cand) & win
            else:
                cand = win
            ii, jj = np.nonzero(cand)
            if len(ii) == 0:
                continue
            stats.candidates += len(ii)
            gi = jnp.asarray(ii + r0)
            gj = jnp.asarray(jj + s0)
            if self_join:
                ok = np.asarray(verify.verify_pairs(
                    tokens_r, lengths_r, gi, gj, sim, float(tau)))
            else:
                ok = np.asarray(verify.verify_pairs_rs(
                    tokens_r, lengths_r, tokens_s, lengths_s, gi, gj,
                    sim, float(tau)))
            if ok.any():
                stats.verified_true += int(ok.sum())
                pairs_out.append(
                    np.stack([order_r[np.asarray(gi)[ok]],
                              order_s[np.asarray(gj)[ok]]], axis=1))

    if pairs_out:
        pairs = np.concatenate(pairs_out, axis=0)
        if self_join:
            lo = np.minimum(pairs[:, 0], pairs[:, 1])
            hi_ = np.maximum(pairs[:, 0], pairs[:, 1])
            pairs = np.stack([lo, hi_], axis=1)
        pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
    else:
        pairs = np.zeros((0, 2), dtype=np.int64)
    if return_stats:
        return pairs, stats
    return pairs


def _window_pair_mask(len_r: np.ndarray, len_s: np.ndarray, sim: str, tau: float) -> np.ndarray:
    lo, hi = bounds.length_bounds(sim, tau, len_r.astype(np.float64)[:, None])
    ls = len_s.astype(np.float64)[None, :]
    mask = (ls >= lo) & (ls <= hi) & (len_r[:, None] > 0) & (len_s[None, :] > 0)
    return mask


# ---------------------------------------------------------------------------
# Distributed ring join (shard_map + collective_permute)
# ---------------------------------------------------------------------------

def ring_join_sharded(
    tokens: jnp.ndarray,
    lengths: jnp.ndarray,
    words: jnp.ndarray,
    *,
    mesh,
    axis: str | tuple[str, ...],
    sim: str,
    tau: float,
    tokens_s: jnp.ndarray | None = None,
    lengths_s: jnp.ndarray | None = None,
    words_s: jnp.ndarray | None = None,
    cutoff: int = 1 << 30,
    impl: str = "ref",
    capacity_per_step: int | None = None,
):
    """Distributed exact join via a ring sweep.

    R is sharded over ``axis`` and stays fixed per device; every ring step
    rotates the S shard (bitmaps + tokens + lengths) one hop with
    ``collective_permute`` while the local R shard runs the fused bitmap
    filter + exact verification against the S block it currently holds.
    After ``n_dev`` steps every pair has been examined exactly once — the
    upper triangle (i < j) for a self-join (S operands omitted), the full
    R×S grid when ``tokens_s``/``lengths_s``/``words_s`` are given.  The
    permuted operands of step k+1 are independent of step k's math, so XLA's
    latency-hiding scheduler can overlap the ICI transfer with tile compute.

    Candidates are compacted into a fixed ``capacity_per_step`` buffer per
    device — the TPU analogue of Algorithm 8's 2048-entry thread-local lists.
    An overflowing step silently truncates its candidate list (``jnp.nonzero``
    drops everything beyond ``cap``), so it is flagged *per step*: the caller
    re-runs exactly the flagged (device, step) tiles densely, preserving
    exactness.

    Returns ``(pairs, valid, counters, overflow_steps)``:
      pairs: int32[n_dev * steps * cap, 2] global (i, j) ids (garbage where
        ``valid`` is False), sharded over ``axis``.
      valid: bool with matching leading dim — verified-similar slots.
      counters: int64[n_dev, 3] per-device (candidates, verified, overflow).
      overflow_steps: bool[n_dev, n_dev] — [device, step] tiles whose
        candidate count exceeded ``cap`` (their pairs are incomplete).
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    rs_join = tokens_s is not None
    if rs_join and (lengths_s is None or words_s is None):
        raise ValueError("R×S ring join needs tokens_s, lengths_s and words_s")
    if not rs_join:
        tokens_s, lengths_s, words_s = tokens, lengths, words

    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    axis_name = axes if len(axes) > 1 else axes[0]
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))
    n_r = tokens.shape[0]
    n_s = tokens_s.shape[0]
    if n_r % n_dev or n_s % n_dev:
        raise ValueError(
            f"collection sizes {n_r}x{n_s} must divide over {n_dev} devices (pad first)")
    shard_r = n_r // n_dev
    shard_s = n_s // n_dev
    cap = capacity_per_step or max(8 * max(shard_r, shard_s), 128)

    spec = P(axes)

    def local(tok, length, word, s_tok0, s_len0, s_word0):
        my = jax.lax.axis_index(axis_name)
        gi = my * shard_r + jnp.arange(shard_r, dtype=jnp.int32)

        def step(carry, t):
            (s_tok, s_len, s_word), (cand_acc, ver_acc, ovf_acc) = carry
            s_dev = (my - t) % n_dev  # origin device of the S shard we hold
            gj = s_dev * shard_s + jnp.arange(shard_s, dtype=jnp.int32)
            cand = kops.candidate_matrix(
                word, s_word, length, s_len,
                sim=sim, tau=float(tau), self_join=False,
                cutoff=int(cutoff), impl=impl)
            if not rs_join:
                cand &= gi[:, None] < gj[None, :]
            n_cand = jnp.sum(cand, dtype=jnp.int32)
            # Fixed-capacity compaction (Algorithm 8's local candidate list).
            ii, jj = jnp.nonzero(cand, size=cap, fill_value=0)
            slot_valid = jnp.arange(cap) < n_cand
            ok = verify.pairwise_overlap(tok[ii], s_tok[jj])
            need = _need(sim, tau, length[ii], s_len[jj])
            ok_mask = slot_valid & (ok >= need)
            out_pairs = jnp.stack([ii + my * shard_r,
                                   jj + s_dev * shard_s], axis=1).astype(jnp.int32)
            perm = [(d, (d + 1) % n_dev) for d in range(n_dev)]
            nxt = tuple(jax.lax.ppermute(x, axis_name, perm)
                        for x in (s_tok, s_len, s_word))
            overflowed = n_cand > cap
            accs = (cand_acc + n_cand.astype(jnp.int64),
                    ver_acc + jnp.sum(ok_mask, dtype=jnp.int64),
                    ovf_acc + overflowed.astype(jnp.int64))
            return (nxt, accs), (out_pairs, ok_mask, overflowed)

        zero = jnp.int64(0)
        init = ((s_tok0, s_len0, s_word0), (zero, zero, zero))
        (_, (cand, ver, ovf)), (pairs, valid, overflow) = jax.lax.scan(
            step, init, jnp.arange(n_dev, dtype=jnp.int32))
        counters = jnp.stack([cand, ver, ovf])[None]  # (1, 3) per device
        return pairs.reshape(-1, 2), valid.reshape(-1), counters, overflow[None]

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec,) * 6,
        out_specs=(P(axes),) * 4,
        check_rep=False,
    )
    return fn(tokens, lengths, words, tokens_s, lengths_s, words_s)


def _need(sim: str, tau: float, lr, ls):
    lr = lr.astype(jnp.float32)
    ls = ls.astype(jnp.float32)
    if sim == "overlap":
        return jnp.full_like(lr + ls, float(tau))
    if sim == "jaccard":
        return (tau / (1.0 + tau)) * (lr + ls)
    if sim == "cosine":
        return tau * jnp.sqrt(lr * ls)
    if sim == "dice":
        return (tau / 2.0) * (lr + ls)
    raise ValueError(sim)
