"""Exact set-similarity join drivers.

Three tiers, mirroring the paper's structure:

* :func:`naive_join` — Algorithm 1, the O(|R|·|S|) oracle (tests/small inputs).
* :func:`blocked_bitmap_join` — the TPU adaptation of the paper's GPU
  Algorithm 8: length-sorted collection, block-level length-filter early-out,
  fused bitmap-filter tiles (Pallas), candidate compaction (on host, or fully
  device-resident with ``compaction="device"``), batched exact verification
  on device. Host drives the block loop (like the GPU host code drives
  kernel launches).
* :func:`ring_join_sharded` / :func:`ring_join` — multi-device version: R is
  sharded over the mesh's batch axes, S blocks circulate via
  ``collective_permute``; each ring step runs the same fused filter +
  fixed-capacity compaction + verification locally.  ``ring_join`` is the
  exactness-preserving driver: it densely re-runs any (device, step) tile
  whose candidate list overflowed.  Used by the dedup pipeline and the
  dry-run.

A fourth driver family lives in :mod:`repro.index`:
``indexed_bitmap_join`` / ``indexed_join_prepared`` generate candidates
from a CSR ℓ-prefix inverted index instead of walking the grid — the only
driver whose work scales with candidate count rather than |R|·|S|.

Every driver accepts plain :class:`~repro.core.collection.Collection` inputs
(prepared internally — the historical one-shot shape) or build-once
:class:`~repro.core.engine.PreparedCollection` artifacts whose cached length
sort, bitmap words and length windows are reused across calls (the serving
shape; see :mod:`repro.core.engine`).

Every driver supports both the paper's general two-collection R×S join and
the optimized self-join special case.  Self-join is selected by omitting the
second collection: ``naive_join(col, sim, tau)`` (the seed calling convention
still works positionally); R×S by passing it: ``naive_join(col_r, col_s, sim,
tau)``.  Self-joins return pairs ``(i, j)`` with ``i < j``; R×S joins return
``(r_index, s_index)`` pairs over the two collections' original indices.

All joins return *exactly* the same pair set as the oracle (property-tested);
the bitmap filter only ever removes pairs that verification would reject.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core import bounds, expected, verify
from repro.core.collection import Collection, split_join_args
from repro.core.constants import BITMAP_COMBINED, JACCARD, PAD_TOKEN
from repro.core.engine import PreparedCollection, as_prepared
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# Oracle
# ---------------------------------------------------------------------------

_normalize_rs_args = split_join_args


def naive_join(col_r: Collection, col_s: Collection | str | None = None,
               sim: str = JACCARD, tau: float = 0.8) -> np.ndarray:
    """Algorithm 1: all verified pairs as int64[K, 2].

    Self-join (``col_s`` omitted) returns pairs with i < j; R×S returns
    (r_index, s_index) over the full cross product.
    """
    col_s, sim, tau = _normalize_rs_args(col_s, sim, tau)
    if isinstance(col_r, PreparedCollection):
        col_r = col_r.source
    if isinstance(col_s, PreparedCollection):
        col_s = col_s.source
    self_join = col_s is None
    if self_join:
        col_s = col_r
    o = _overlap_matrix(jnp.asarray(col_r.tokens), jnp.asarray(col_s.tokens))
    len_r = np.asarray(col_r.lengths)
    len_s = np.asarray(col_s.lengths)
    need = bounds.equivalent_overlap(sim, tau, len_r[:, None], len_s[None, :])
    simmat = np.asarray(o) >= need
    # Empty sets (padding) are never similar to anything — the vacuous
    # 0 >= 0 case for normalised similarities is excluded, matching the
    # paper's definition over non-empty sets.
    simmat &= (len_r > 0)[:, None] & (len_s > 0)[None, :]
    if self_join:
        iu = np.triu_indices(col_r.num_sets, k=1)
        mask = simmat[iu]
        return np.stack([iu[0][mask], iu[1][mask]], axis=1).astype(np.int64)
    ii, jj = np.nonzero(simmat)
    return np.stack([ii, jj], axis=1).astype(np.int64)


@jax.jit
def _overlap_matrix(tokens_r: jnp.ndarray, tokens_s: jnp.ndarray) -> jnp.ndarray:
    def row_vs_all(row):
        return jax.vmap(lambda s: verify._row_overlap(row, s))(tokens_s)

    return jax.vmap(row_vs_all)(tokens_r)


# ---------------------------------------------------------------------------
# Blocked device join (Algorithm 8, TPU-native)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JoinStats:
    """Observability counters (paper Tables 9-10 are derived from these).

    ``total_pairs`` is always the number of cells the bitmap filter's
    verdict was actually *consumed* on: window-surviving grid cells for the
    grid drivers (``naive``/``blocked``/``ring``), index-generated deduped
    candidates for the ``indexed`` driver — so ``filter_ratio`` measures the
    bitmap's pruning over its real input for every driver.  The candidate
    funnel is reported explicitly by ``candidates_generated`` (==
    ``total_pairs``) → ``candidates`` (after the bitmap) →
    ``verified_true``; ``postings_expanded`` additionally records the
    indexed driver's pre-dedup postings volume (0 for grid drivers).
    """

    total_pairs: int = 0          # pairs the bitmap verdict is consumed on
    blocks_total: int = 0         # block pairs / probe chunks walked
    blocks_skipped: int = 0       # pruned by the length filter / empty chunks
    candidates: int = 0           # pairs surviving the bitmap filter
    verified_true: int = 0        # final result size
    overflow_blocks: int = 0      # tiles/chunks escalated to the dense path
    candidates_generated: int = 0  # pre-bitmap candidate pairs (the funnel top)
    postings_expanded: int = 0    # indexed driver: pre-dedup postings entries

    @property
    def filter_ratio(self) -> float:
        """Fraction of length-surviving pairs pruned by the bitmap filter."""
        if self.total_pairs == 0:
            return 0.0
        return 1.0 - self.candidates / self.total_pairs

    @property
    def precision(self) -> float:
        """true positives / unfiltered (Section 5.1.3)."""
        if self.candidates == 0:
            return 1.0
        return self.verified_true / self.candidates

    def to_dict(self) -> dict:
        """Counters + derived ratios as plain JSON-able types (benchmarks
        emit these so filter-ratio/perf trajectories can be diffed)."""
        d = dataclasses.asdict(self)
        d["filter_ratio"] = self.filter_ratio
        d["precision"] = self.precision
        return d


def _bucket_capacity(n: int, floor: int = 128) -> int:
    """Round a measured candidate count up to a power of two (>= floor).

    The compaction capacity is a static (compile-time) size; bucketing keeps
    the number of distinct jit variants logarithmic in the observed counts.
    """
    return max(floor, 1 << max(int(n) - 1, 0).bit_length())


@functools.partial(
    jax.jit,
    static_argnames=("sim", "tau", "cap", "diag", "cutoff", "impl", "use_bitmap"),
)
def _resident_block_step(
    tokens_r, lengths_r, words_r, tokens_s, lengths_s, words_s,
    lo_s, hi_s, need_tab, r0, s0,
    *, sim: str, tau: float, cap: int, diag: bool, cutoff: int, impl: str,
    use_bitmap: bool = True,
):
    """One fused, fully device-resident block-pair step (Algorithm 8's local
    candidate list, TPU-shaped).

    Bitmap verdict -> integer length-window mask -> fixed-capacity compaction
    (``jnp.nonzero(size=cap)``) -> exact searchsorted verification -> second
    compaction down to verified pairs, all inside one jit.  Only the
    ``(cap, 2)`` compacted pair buffer and five scalars are ever transferred
    to the host; the dense ``(TR, TS)`` verdict tile never leaves the device.

    Returns ``(pairs, n_win, n_cand, n_ok, overflow)``: global sorted-index
    pairs (slots ``>= n_ok`` are garbage), window-pair / candidate / verified
    counts, and whether the candidate count exceeded ``cap`` (the caller then
    escalates this block pair to the dense host-compaction path).
    """
    win = ((lengths_s[None, :] >= lo_s[:, None])
           & (lengths_s[None, :] <= hi_s[:, None])
           & (lengths_r[:, None] > 0) & (lengths_s[None, :] > 0))
    if diag:
        win &= (jnp.arange(win.shape[0])[:, None]
                < jnp.arange(win.shape[1])[None, :])
    if use_bitmap:
        cand = kops.candidate_matrix(
            words_r, words_s, lengths_r, lengths_s, sim=sim, tau=tau,
            self_join=False, cutoff=cutoff, impl=impl) & win
    else:
        cand = win
    n_win = jnp.sum(win, dtype=jnp.int32)
    n_cand = jnp.sum(cand, dtype=jnp.int32)
    ii, jj = jnp.nonzero(cand, size=cap, fill_value=0)
    slot_ok = jnp.arange(cap) < n_cand
    o = verify.pairwise_overlap(tokens_r[ii], tokens_s[jj])
    # Integer-exact acceptance (min_overlap_table): bit-identical to the
    # f64 oracle — f32 thresholds may only ever *prune*, never accept.
    need = bounds.min_overlap_gather(sim, need_tab, lengths_r[ii],
                                     lengths_s[jj])
    ok = slot_ok & (o >= need)
    n_ok = jnp.sum(ok, dtype=jnp.int32)
    vi = jnp.nonzero(ok, size=cap, fill_value=0)[0]
    pairs = jnp.stack([ii[vi].astype(jnp.int32) + r0,
                       jj[vi].astype(jnp.int32) + s0], axis=1)
    return pairs, n_win, n_cand, n_ok, n_cand > cap


def _dense_block_verify(
    tokens_r, lengths_r, words_r, tokens_s, lengths_s, words_s,
    np_len_r, np_len_s, r0, r1, s0, s1,
    *, sim, tau, cutoff, impl, diag, self_join, use_bitmap=True,
):
    """Host-compaction path for one block pair: dense mask -> ``np.nonzero``
    on host -> batched exact verification.  The classic route, and the dense
    escalation target when a device-resident tile overflows its capacity.

    Returns ``(n_win, n_cand, verified sorted-index pairs int64[K, 2])``.
    """
    win = _window_pair_mask(np_len_r[r0:r1], np_len_s[s0:s1], sim, tau)
    if diag:
        win = np.triu(win, k=1)
    if use_bitmap:
        cand = kops.candidate_matrix(
            words_r[r0:r1], words_s[s0:s1],
            lengths_r[r0:r1], lengths_s[s0:s1],
            sim=sim, tau=float(tau), self_join=False,
            cutoff=int(cutoff), impl=impl)
        # The fused kernel does not apply the length filter; without this
        # intersection `candidates` could exceed `total_pairs` and
        # filter_ratio could go negative.
        cand = np.asarray(cand) & win
    else:
        cand = win
    n_win = int(win.sum())
    ii, jj = np.nonzero(cand)
    if len(ii) == 0:
        return n_win, 0, np.zeros((0, 2), dtype=np.int64)
    gi = jnp.asarray(ii + r0)
    gj = jnp.asarray(jj + s0)
    if self_join:
        ok = np.asarray(verify.verify_pairs(
            tokens_r, lengths_r, gi, gj, sim, float(tau)))
    else:
        ok = np.asarray(verify.verify_pairs_rs(
            tokens_r, lengths_r, tokens_s, lengths_s, gi, gj,
            sim, float(tau)))
    pairs = np.stack([np.asarray(gi)[ok], np.asarray(gj)[ok]], axis=1)
    return n_win, len(ii), pairs.astype(np.int64)


def blocked_bitmap_join(
    col_r: Collection | PreparedCollection,
    col_s: Collection | PreparedCollection | str | None = None,
    sim: str = JACCARD,
    tau: float = 0.8,
    *,
    b: int = 128,
    method: str = BITMAP_COMBINED,
    mix: bool = False,
    block: int = 4096,
    impl: str = "auto",
    use_cutoff: bool = True,
    use_bitmap: bool = True,
    compaction: str = "host",
    capacity: int | None = None,
    return_stats: bool = False,
):
    """Exact join; returns int64[K, 2] pairs in original indices.

    Thin wrapper over :func:`blocked_bitmap_join_prepared`: plain
    ``Collection`` inputs are prepared on the spot (one-shot call, today's
    behaviour bit-for-bit), :class:`~repro.core.engine.PreparedCollection`
    inputs reuse their cached length sort / bitmap words / length windows
    across calls (the serving shape — see ``repro.core.engine.JoinEngine``).

    The driver walks block pairs of the length-sorted collections — the full
    R×S grid for two collections, the upper triangle for a self-join. Because
    blocks are length-contiguous, the Table 2 length window prunes whole block
    pairs in both directions (the TPU analogue of the paper's sorted
    inverted-list early termination).

    Surviving block pairs run one of two compaction modes:

    * ``compaction="host"`` — the original path: the fused bitmap kernel's
      dense bool tile is shipped to the host, ``np.nonzero`` compacts it
      there, and the candidate indices round-trip back for verification.
    * ``compaction="device"`` — the resident path (the paper's Algorithm 8
      local candidate lists): a tile-count prepass (`kops.count_candidates`)
      measures the real candidate count, a power-of-two capacity is sized
      from it, and one jit'd step fuses verdict -> length-window mask ->
      fixed-capacity compaction -> exact verification, so only compacted
      ``(i, j)`` pairs and counters ever cross to the host.  Passing an
      explicit ``capacity`` skips the prepass; a block pair whose candidate
      count exceeds it is flagged and escalated to the dense host path
      (``JoinStats.overflow_blocks`` counts these), preserving exactness.

    Both modes return identical pairs and bit-identical ``JoinStats``
    counters (property-tested against the ``naive_join`` oracle).
    """
    col_s, sim, tau = _normalize_rs_args(col_s, sim, tau)
    return blocked_bitmap_join_prepared(
        as_prepared(col_r), None if col_s is None else as_prepared(col_s),
        sim=sim, tau=tau, b=b, method=method, mix=mix, block=block,
        impl=impl, use_cutoff=use_cutoff, use_bitmap=use_bitmap,
        compaction=compaction, capacity=capacity, return_stats=return_stats)


def blocked_bitmap_join_prepared(
    prep_r: PreparedCollection,
    prep_s: PreparedCollection | None = None,
    *,
    sim: str = JACCARD,
    tau: float = 0.8,
    b: int = 128,
    method: str = BITMAP_COMBINED,
    mix: bool = False,
    block: int = 4096,
    impl: str = "auto",
    use_cutoff: bool = True,
    use_bitmap: bool = True,
    compaction: str = "host",
    capacity: int | None = None,
    return_stats: bool = False,
):
    """The blocked join over prepared inputs (see :func:`blocked_bitmap_join`
    for the full driver contract).

    Everything derivable from the collection alone comes from the
    :class:`~repro.core.engine.PreparedCollection` caches: the length-sorted
    arrays and inverse permutation, the packed bitmap words keyed by
    ``(b, method, mix)``, and the integer length windows keyed by
    ``(sim, tau)``.  Repeated probes against the same prepared collection
    skip the length sort and bitmap generation entirely (assertable via
    ``prep.builds``).
    """
    if compaction not in ("host", "device"):
        raise ValueError(f"compaction must be 'host' or 'device', got {compaction!r}")
    # Self-join ONLY when S is omitted: passing the same prepared object as
    # both operands is an R×S join over the full cross product (including
    # the diagonal), matching the plain-Collection wrapper's semantics.
    self_join = prep_s is None
    if self_join:
        prep_s = prep_r
    order_r, order_s = prep_r.order, prep_s.order
    nr, ns = prep_r.num_sets, prep_s.num_sets
    tokens_r, lengths_r = prep_r.device_arrays()
    tokens_s, lengths_s = prep_s.device_arrays()

    if method == BITMAP_COMBINED:
        chosen = bm.choose_method(tau, b)
    else:
        chosen = method
    cutoff = expected.cutoff_point(chosen, b, float(tau)) if use_cutoff else 1 << 30
    words_r = prep_r.bitmap_words(b, chosen, mix=mix)
    words_s = words_r if self_join else prep_s.bitmap_words(b, chosen, mix=mix)

    np_len_r = prep_r.lengths
    np_len_s = prep_s.lengths
    stats = JoinStats()
    pairs_out: list[np.ndarray] = []
    nb_r = math.ceil(nr / block)
    nb_s = math.ceil(ns / block)
    if compaction == "device":
        # Cached integer windows for every sorted row (built at most once per
        # (sim, tau) over this prepared collection; block rows slice it).
        _, _, full_lo, full_hi = prep_r.length_window_int(sim, tau)
        need_tab = verify.min_overlap_table_dev(
            sim, float(tau), prep_r.max_len, prep_s.max_len)

    for bi in range(nb_r):
        r0, r1 = bi * block, min((bi + 1) * block, nr)
        min_lr = int(np_len_r[r0])
        max_lr = int(np_len_r[r1 - 1])
        # Admissible |s| window for the whole R block: the length bounds are
        # nondecreasing in |r|, so the block-wide window is
        # [lo(min |r|), hi(max |r|)] — integer-exact via length_window_int,
        # the same single source of truth as the per-pair window (the raw
        # float bounds can exclude boundary partners the verifier accepts).
        blk_lo, blk_hi = bounds.length_window_int(
            sim, tau, np.array([max(min_lr, 1), max(max_lr, 1)]))
        lo_r0, hi_r1 = int(blk_lo[0]), int(blk_hi[1])
        for bj in range(bi if self_join else 0, nb_s):
            s0, s1 = bj * block, min((bj + 1) * block, ns)
            stats.blocks_total += 1
            min_ls = int(np_len_s[s0])
            max_ls = int(np_len_s[s1 - 1])
            # Blocks are length-sorted: if the smallest |s| already exceeds
            # the window every later bj fails too (terminate the row) ...
            if min_ls > hi_r1:
                stats.blocks_total += nb_s - bj - 1
                stats.blocks_skipped += nb_s - bj
                break
            # ... and if the largest |s| is still below it, only this bj
            # fails (later blocks hold longer sets).
            if max_ls < lo_r0:
                stats.blocks_skipped += 1
                continue
            diag = self_join and bi == bj

            if compaction == "host":
                n_win, n_cand, vpairs = _dense_block_verify(
                    tokens_r, lengths_r, words_r, tokens_s, lengths_s, words_s,
                    np_len_r, np_len_s, r0, r1, s0, s1,
                    sim=sim, tau=tau, cutoff=cutoff, impl=impl, diag=diag,
                    self_join=self_join, use_bitmap=use_bitmap)
                stats.total_pairs += n_win
                stats.candidates += n_cand
                stats.verified_true += len(vpairs)
                if len(vpairs):
                    pairs_out.append(np.stack(
                        [order_r[vpairs[:, 0]], order_s[vpairs[:, 1]]], axis=1))
                continue

            # --- device-resident compaction ---
            win_lo, win_hi = full_lo[r0:r1], full_hi[r0:r1]
            if capacity is None:
                # Tile-count prepass: size the capacity from the real counts
                # (only two int32 grids cross to the host).
                nwin_t, ncand_t = kops.count_candidates(
                    words_r[r0:r1], words_s[s0:s1],
                    lengths_r[r0:r1], lengths_s[s0:s1], win_lo, win_hi,
                    sim=sim, tau=float(tau), self_join=diag,
                    cutoff=int(cutoff), impl=impl)
                n_win = int(np.asarray(nwin_t).sum())
                n_cand_pre = int(np.asarray(ncand_t).sum())
                stats.total_pairs += n_win
                if not use_bitmap:
                    n_cand_pre = n_win
                if n_cand_pre == 0:
                    continue
                cap = min(_bucket_capacity(n_cand_pre), (r1 - r0) * (s1 - s0))
            else:
                cap = int(capacity)
            pairs_d, n_win_d, n_cand_d, n_ok_d, ovf = _resident_block_step(
                tokens_r[r0:r1], lengths_r[r0:r1], words_r[r0:r1],
                tokens_s[s0:s1], lengths_s[s0:s1], words_s[s0:s1],
                win_lo, win_hi, need_tab, jnp.int32(r0), jnp.int32(s0),
                sim=sim, tau=float(tau), cap=cap, diag=diag,
                cutoff=int(cutoff), impl=impl, use_bitmap=use_bitmap)
            if capacity is not None:
                stats.total_pairs += int(n_win_d)
            stats.candidates += int(n_cand_d)
            if bool(ovf):
                # Escalation: the fixed-capacity list truncated this tile —
                # re-run it densely (host compaction) for exactness.  The
                # counters above are exact (counted before truncation).
                stats.overflow_blocks += 1
                _, _, vpairs = _dense_block_verify(
                    tokens_r, lengths_r, words_r, tokens_s, lengths_s, words_s,
                    np_len_r, np_len_s, r0, r1, s0, s1,
                    sim=sim, tau=tau, cutoff=cutoff, impl=impl, diag=diag,
                    self_join=self_join, use_bitmap=use_bitmap)
                stats.verified_true += len(vpairs)
                if len(vpairs):
                    pairs_out.append(np.stack(
                        [order_r[vpairs[:, 0]], order_s[vpairs[:, 1]]], axis=1))
                continue
            k = int(n_ok_d)
            stats.verified_true += k
            if k:
                vp = np.asarray(pairs_d)[:k].astype(np.int64)
                pairs_out.append(np.stack(
                    [order_r[vp[:, 0]], order_s[vp[:, 1]]], axis=1))

    if pairs_out:
        pairs = np.concatenate(pairs_out, axis=0)
        if self_join:
            lo = np.minimum(pairs[:, 0], pairs[:, 1])
            hi_ = np.maximum(pairs[:, 0], pairs[:, 1])
            pairs = np.stack([lo, hi_], axis=1)
        pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
    else:
        pairs = np.zeros((0, 2), dtype=np.int64)
    # Grid driver: the bitmap verdict is consumed on every window-surviving
    # cell, so the funnel top equals the windowed grid (set identically on
    # both compaction paths — the stats stay bit-for-bit comparable).
    stats.candidates_generated = stats.total_pairs
    if return_stats:
        return pairs, stats
    return pairs


def _window_pair_mask(len_r: np.ndarray, len_s: np.ndarray, sim: str, tau: float) -> np.ndarray:
    # Integer-exact form of the Table 2 window: identical to comparing the
    # real-valued bounds (lengths are integers), and the same int32 test the
    # device-resident step applies — so host and device paths agree on
    # `total_pairs` bit-for-bit.
    lo_i, hi_i = bounds.length_window_int(sim, tau, len_r)
    ls = len_s[None, :]
    return ((ls >= lo_i[:, None]) & (ls <= hi_i[:, None])
            & (len_r[:, None] > 0) & (len_s[None, :] > 0))


# ---------------------------------------------------------------------------
# Distributed ring join (shard_map + collective_permute)
# ---------------------------------------------------------------------------

_RING_ENTRYPOINTS = None


def _ring_entrypoint_cache():
    """The ring driver's traced-factory cache — a
    :class:`repro.serve.entrypoints.EntrypointCache` (lazy: ``repro.serve``
    imports the engine, so the import happens at first probe, not at module
    load)."""
    global _RING_ENTRYPOINTS
    if _RING_ENTRYPOINTS is None:
        from repro.serve.entrypoints import EntrypointCache
        _RING_ENTRYPOINTS = EntrypointCache(maxsize=256)
    return _RING_ENTRYPOINTS


def _ring_sweep_fn(mesh, axes, *, shard_r: int, shard_s: int, cap: int,
                   sim: str, tau: float, cutoff: int, impl: str,
                   rs_join: bool):
    """Memoized traced factory for the ring sweep: repeated ring joins with
    the same mesh/shape/knobs — the engine's probe loop, the conformance
    sweep — reuse the compiled executable instead of re-tracing a fresh
    closure per call (the jit cache then keys on operand shapes as usual).
    """
    key = ("ring_sweep", mesh, axes, shard_r, shard_s, cap, sim, tau,
           cutoff, impl, rs_join)
    return _ring_entrypoint_cache().get(
        key, lambda: _build_ring_sweep_fn(
            mesh, axes, shard_r=shard_r, shard_s=shard_s, cap=cap, sim=sim,
            tau=tau, cutoff=cutoff, impl=impl, rs_join=rs_join))


def _build_ring_sweep_fn(mesh, axes, *, shard_r: int, shard_s: int, cap: int,
                         sim: str, tau: float, cutoff: int, impl: str,
                         rs_join: bool):
    """Compile (once per static ring config) the jitted shard_map sweep."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    axis_name = axes if len(axes) > 1 else axes[0]
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))
    spec = P(axes)

    def local(tok, length, word, s_tok0, s_len0, s_word0, ntab):
        my = jax.lax.axis_index(axis_name)
        gi = my * shard_r + jnp.arange(shard_r, dtype=jnp.int32)

        def step(carry, t):
            (s_tok, s_len, s_word), (cand_acc, ver_acc, ovf_acc) = carry
            s_dev = (my - t) % n_dev  # origin device of the S shard we hold
            gj = s_dev * shard_s + jnp.arange(shard_s, dtype=jnp.int32)
            cand = kops.candidate_matrix(
                word, s_word, length, s_len,
                sim=sim, tau=float(tau), self_join=False,
                cutoff=int(cutoff), impl=impl)
            if not rs_join:
                cand &= gi[:, None] < gj[None, :]
            n_cand = jnp.sum(cand, dtype=jnp.int32)
            # Fixed-capacity compaction (Algorithm 8's local candidate list).
            ii, jj = jnp.nonzero(cand, size=cap, fill_value=0)
            slot_valid = jnp.arange(cap) < n_cand
            ok = verify.pairwise_overlap(tok[ii], s_tok[jj])
            need = bounds.min_overlap_gather(sim, ntab, length[ii], s_len[jj])
            ok_mask = slot_valid & (ok >= need)
            out_pairs = jnp.stack([ii + my * shard_r,
                                   jj + s_dev * shard_s], axis=1).astype(jnp.int32)
            perm = [(d, (d + 1) % n_dev) for d in range(n_dev)]
            nxt = tuple(jax.lax.ppermute(x, axis_name, perm)
                        for x in (s_tok, s_len, s_word))
            overflowed = n_cand > cap
            accs = (cand_acc + n_cand.astype(jnp.int64),
                    ver_acc + jnp.sum(ok_mask, dtype=jnp.int64),
                    ovf_acc + overflowed.astype(jnp.int64))
            return (nxt, accs), (out_pairs, ok_mask, overflowed)

        zero = jnp.int64(0)
        init = ((s_tok0, s_len0, s_word0), (zero, zero, zero))
        (_, (cand, ver, ovf)), (pairs, valid, overflow) = jax.lax.scan(
            step, init, jnp.arange(n_dev, dtype=jnp.int32))
        counters = jnp.stack([cand, ver, ovf])[None]  # (1, 3) per device
        return pairs.reshape(-1, 2), valid.reshape(-1), counters, overflow[None]

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec,) * 6 + (P(),),
        out_specs=(P(axes),) * 4,
        check_rep=False,
    )
    return jax.jit(fn)


def ring_join_sharded(
    tokens: jnp.ndarray,
    lengths: jnp.ndarray,
    words: jnp.ndarray,
    *,
    mesh,
    axis: str | tuple[str, ...],
    sim: str,
    tau: float,
    tokens_s: jnp.ndarray | None = None,
    lengths_s: jnp.ndarray | None = None,
    words_s: jnp.ndarray | None = None,
    cutoff: int = 1 << 30,
    impl: str = "ref",
    capacity_per_step: int | None = None,
):
    """Distributed exact join via a ring sweep.

    R is sharded over ``axis`` and stays fixed per device; every ring step
    rotates the S shard (bitmaps + tokens + lengths) one hop with
    ``collective_permute`` while the local R shard runs the fused bitmap
    filter + exact verification against the S block it currently holds.
    After ``n_dev`` steps every pair has been examined exactly once — the
    upper triangle (i < j) for a self-join (S operands omitted), the full
    R×S grid when ``tokens_s``/``lengths_s``/``words_s`` are given.  The
    permuted operands of step k+1 are independent of step k's math, so XLA's
    latency-hiding scheduler can overlap the ICI transfer with tile compute.

    Candidates are compacted into a fixed ``capacity_per_step`` buffer per
    device — the TPU analogue of Algorithm 8's 2048-entry thread-local lists.
    An overflowing step silently truncates its candidate list (``jnp.nonzero``
    drops everything beyond ``cap``), so it is flagged *per step*: the
    :func:`ring_join` driver re-runs exactly the flagged (device, step) tiles
    densely and merges the results, preserving exactness.  Call that wrapper
    unless you want to handle the escalation yourself.

    Returns ``(pairs, valid, counters, overflow_steps)``:
      pairs: int32[n_dev * steps * cap, 2] global (i, j) ids (garbage where
        ``valid`` is False), sharded over ``axis``.
      valid: bool with matching leading dim — verified-similar slots.
      counters: int64[n_dev, 3] per-device (candidates, verified, overflow).
      overflow_steps: bool[n_dev, n_dev] — [device, step] tiles whose
        candidate count exceeded ``cap`` (their pairs are incomplete).
    """
    from repro.distributed.sharding import join_axes

    rs_join = tokens_s is not None
    if rs_join and (lengths_s is None or words_s is None):
        raise ValueError("R×S ring join needs tokens_s, lengths_s and words_s")
    if not rs_join:
        tokens_s, lengths_s, words_s = tokens, lengths, words

    axes, _axis_name, n_dev = join_axes(mesh, axis)
    n_r = tokens.shape[0]
    n_s = tokens_s.shape[0]
    if n_r % n_dev or n_s % n_dev:
        raise ValueError(
            f"collection sizes {n_r}x{n_s} must divide over {n_dev} devices (pad first)")
    shard_r = n_r // n_dev
    shard_s = n_s // n_dev
    cap = capacity_per_step or max(8 * max(shard_r, shard_s), 128)

    # Integer acceptance thresholds, replicated to every device (f32 math
    # may only prune; membership is decided by this host-built table).
    need_tab = verify.min_overlap_table_dev(
        sim, float(tau), int(tokens.shape[1]), int(tokens_s.shape[1]))

    fn = _ring_sweep_fn(
        mesh, axes, shard_r=shard_r, shard_s=shard_s, cap=int(cap),
        sim=sim, tau=float(tau), cutoff=int(cutoff), impl=impl,
        rs_join=rs_join)
    return fn(tokens, lengths, words, tokens_s, lengths_s, words_s, need_tab)


def ring_join(
    tokens: jnp.ndarray,
    lengths: jnp.ndarray,
    words: jnp.ndarray,
    *,
    mesh,
    axis: str | tuple[str, ...],
    sim: str,
    tau: float,
    tokens_s: jnp.ndarray | None = None,
    lengths_s: jnp.ndarray | None = None,
    words_s: jnp.ndarray | None = None,
    cutoff: int = 1 << 30,
    impl: str = "ref",
    capacity_per_step: int | None = None,
    return_stats: bool = False,
):
    """Exact distributed join: ring sweep + dense re-run of overflowed tiles.

    Drives :func:`ring_join_sharded` and implements the escalation its
    fixed-capacity compaction requires: every flagged ``(device, step)`` tile
    — one R shard against the S shard it held at that step, whose candidate
    count exceeded ``capacity_per_step`` — is recomputed densely (fused
    bitmap filter, host compaction, batched exact verification) and its
    complete pair set replaces the truncated one.  Tiles that did not
    overflow are taken from the ring output as-is, so the re-run cost is
    proportional to the overflowed fraction only.

    Returns the final exact pair set as lexicographically sorted
    ``int64[K, 2]`` global indices — ``(i, j)`` with ``i < j`` for a
    self-join (S operands omitted), ``(r_index, s_index)`` otherwise — i.e.
    exactly :func:`naive_join`'s pairs over the same (padded) arrays.  With
    ``return_stats=True`` also returns ``(counters, overflow_steps)`` as
    numpy arrays (see :func:`ring_join_sharded`); the per-device verified
    counters are reconciled with the dense re-runs, so
    ``counters[:, 1].sum() == len(pairs)`` even under overflow.
    """
    from repro.distributed.sharding import join_axes

    rs_join = tokens_s is not None
    if not rs_join:
        tokens_s, lengths_s, words_s = tokens, lengths, words
    _axes, _name, n_dev = join_axes(mesh, axis)
    shard_r = tokens.shape[0] // n_dev
    shard_s = tokens_s.shape[0] // n_dev

    pairs_d, valid_d, counters_d, overflow_d = ring_join_sharded(
        tokens, lengths, words, mesh=mesh, axis=axis, sim=sim, tau=tau,
        tokens_s=tokens_s if rs_join else None,
        lengths_s=lengths_s if rs_join else None,
        words_s=words_s if rs_join else None,
        cutoff=cutoff, impl=impl, capacity_per_step=capacity_per_step)

    pairs = np.asarray(pairs_d)
    valid = np.asarray(valid_d)
    counters = np.array(counters_d)  # writable: verified gets reconciled below
    overflow = np.asarray(overflow_d)
    cap = pairs.shape[0] // (n_dev * n_dev)
    p4 = pairs.reshape(n_dev, n_dev, cap, 2)
    v3 = valid.reshape(n_dev, n_dev, cap)
    # Complete tiles keep their ring output; overflowed tiles are dropped
    # wholesale (their candidate list was truncated) and recomputed densely.
    out = [p4[v3 & ~overflow[:, :, None]].reshape(-1, 2)]
    for d, t in zip(*np.nonzero(overflow)):
        s_dev = (int(d) - int(t)) % n_dev
        r_sl = slice(int(d) * shard_r, (int(d) + 1) * shard_r)
        s_sl = slice(s_dev * shard_s, (s_dev + 1) * shard_s)
        cand = np.asarray(kops.candidate_matrix(
            words[r_sl], words_s[s_sl], lengths[r_sl], lengths_s[s_sl],
            sim=sim, tau=float(tau), self_join=False,
            cutoff=int(cutoff), impl=impl))
        ii, jj = np.nonzero(cand)
        gi = ii + int(d) * shard_r
        gj = jj + s_dev * shard_s
        if not rs_join:
            keep = gi < gj
            gi, gj = gi[keep], gj[keep]
        n_ok = 0
        if len(gi):
            ok = np.asarray(verify.verify_pairs_rs(
                tokens, lengths, tokens_s, lengths_s,
                jnp.asarray(gi), jnp.asarray(gj), sim, float(tau)))
            n_ok = int(ok.sum())
            if n_ok:
                out.append(np.stack([gi[ok], gj[ok]], axis=1))
        # Reconcile the per-device verified counter: the ring step only saw
        # the <= cap truncated slots of this tile.
        counters[int(d), 1] += n_ok - int(v3[int(d), int(t)].sum())
    merged = np.concatenate(out, axis=0).astype(np.int64)
    merged = merged[np.lexsort((merged[:, 1], merged[:, 0]))]
    if return_stats:
        return merged, counters, overflow
    return merged


def _pad_rows_np(a: np.ndarray, n_total: int, fill) -> np.ndarray:
    if a.shape[0] >= n_total:
        return a
    pad = np.full((n_total - a.shape[0],) + a.shape[1:], fill, dtype=a.dtype)
    return np.concatenate([a, pad], axis=0)


def ring_join_prepared(
    prep_r: PreparedCollection,
    prep_s: PreparedCollection | None = None,
    *,
    mesh,
    axis: str | tuple[str, ...],
    sim: str = JACCARD,
    tau: float = 0.8,
    b: int = 128,
    method: str = BITMAP_COMBINED,
    mix: bool = False,
    use_cutoff: bool = True,
    impl: str = "ref",
    capacity_per_step: int | None = None,
    return_stats: bool = False,
):
    """Collection-level front end of :func:`ring_join` over prepared inputs.

    Handles everything the array-level driver leaves to the caller: bitmap
    words come from the prepared cache (built at most once per
    ``(b, method, mix)``), collections are padded with empty sets up to a
    multiple of the mesh's device count (empty sets are never similar to
    anything, so padding never changes the result), and the returned pairs
    are remapped from the padded sorted space back to *original* collection
    indices — ``(i, j)`` with ``i < j`` for a self-join, ``(r_index,
    s_index)`` otherwise, lexicographically sorted, exactly
    :func:`naive_join`'s pair set.

    With ``return_stats=True`` returns ``(pairs, counters, overflow_steps)``
    (see :func:`ring_join_sharded` for their shapes).
    """
    # Self-join ONLY when S is omitted (same contract as the blocked driver:
    # an explicit S — even the same object — is a full R×S cross product).
    self_join = prep_s is None
    if self_join:
        prep_s = prep_r
    if method == BITMAP_COMBINED:
        chosen = bm.choose_method(tau, b)
    else:
        chosen = method
    cutoff = expected.cutoff_point(chosen, b, float(tau)) if use_cutoff else 1 << 30

    from repro.distributed.sharding import join_axes

    _axes, _name, n_dev = join_axes(mesh, axis)
    nr, ns = prep_r.num_sets, prep_s.num_sets
    nr_pad = math.ceil(nr / n_dev) * n_dev
    ns_pad = math.ceil(ns / n_dev) * n_dev

    words_r = np.asarray(prep_r.bitmap_words(b, chosen, mix=mix))
    tokens = jnp.asarray(_pad_rows_np(prep_r.tokens, nr_pad, PAD_TOKEN))
    lengths = jnp.asarray(_pad_rows_np(prep_r.lengths, nr_pad, 0))
    # Empty sets hash to all-zero bitmaps, so zero-filled padding rows are
    # exactly what generate_bitmaps would produce for them.
    words = jnp.asarray(_pad_rows_np(words_r, nr_pad, 0))
    if self_join:
        rs_kw = {}
    else:
        words_s = np.asarray(prep_s.bitmap_words(b, chosen, mix=mix))
        rs_kw = dict(
            tokens_s=jnp.asarray(_pad_rows_np(prep_s.tokens, ns_pad, PAD_TOKEN)),
            lengths_s=jnp.asarray(_pad_rows_np(prep_s.lengths, ns_pad, 0)),
            words_s=jnp.asarray(_pad_rows_np(words_s, ns_pad, 0)))

    out = ring_join(tokens, lengths, words, mesh=mesh, axis=axis, sim=sim,
                    tau=float(tau), cutoff=int(cutoff), impl=impl,
                    capacity_per_step=capacity_per_step, return_stats=True,
                    **rs_kw)
    sorted_pairs, counters, overflow = out
    # Padded rows have length 0 and can never appear; keep the guard anyway.
    keep = (sorted_pairs[:, 0] < nr) & (sorted_pairs[:, 1] < ns)
    sorted_pairs = sorted_pairs[keep]
    gi = prep_r.order[sorted_pairs[:, 0]]
    gj = (prep_r.order if self_join else prep_s.order)[sorted_pairs[:, 1]]
    if self_join:
        pairs = np.stack([np.minimum(gi, gj), np.maximum(gi, gj)], axis=1)
    else:
        pairs = np.stack([gi, gj], axis=1)
    pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))].astype(np.int64)
    if return_stats:
        return pairs, counters, overflow
    return pairs
