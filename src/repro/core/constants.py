"""Shared constants for the set-similarity core."""

import numpy as np

# Padding token for packed (padded) token arrays. Sorted sets keep pads at the
# end because PAD is the largest int32.
PAD_TOKEN: int = np.iinfo(np.int32).max

# Similarity function identifiers (Table 1 of the paper).
OVERLAP = "overlap"
JACCARD = "jaccard"
COSINE = "cosine"
DICE = "dice"

SIM_FUNCTIONS = (OVERLAP, JACCARD, COSINE, DICE)

# Bitmap generation methods (Section 3.2).
BITMAP_SET = "set"
BITMAP_XOR = "xor"
BITMAP_NEXT = "next"
BITMAP_COMBINED = "combined"

BITMAP_METHODS = (BITMAP_SET, BITMAP_XOR, BITMAP_NEXT)
