"""The prepared-collection engine: build-once join artifacts + batched probes.

The paper splits bitmap *construction* (Section 3.2, Algorithms 3-5) from
per-pair *filtering*; every driver in this repo used to fuse the two anyway,
re-deriving the length sort, the packed bitmap words and the prefix indexes
on each call.  This module makes the build a first-class, reusable artifact:

* :class:`PreparedCollection` — a length-sorted view of a
  :class:`~repro.core.collection.Collection` with the inverse permutation,
  lazily-cached packed bitmap words keyed by ``(b, method, mix)``, cached
  integer length windows (``bounds.length_window_int``) keyed by
  ``(sim, tau)``, and a cached CPU prefix index keyed by ``(sim, tau, ell)``.
  Build counters record exactly which artifacts were (re)built, so reuse is
  assertable, not just hoped for.
* :func:`prepare` / :func:`as_prepared` — construction helpers; every join
  driver accepts either a plain ``Collection`` or a ``PreparedCollection``.
* :class:`JoinEngine` — the serving shape: prepare R once, stream batches of
  S through :meth:`JoinEngine.probe`, each batch returning pairs plus a
  per-batch :class:`~repro.core.join.JoinStats`.  The driver and its knobs
  come from an explicit :class:`~repro.core.plan.JoinPlan`; executable
  drivers are naive / blocked / ring / indexed (the :mod:`repro.index`
  postings-CSR candidate generator) / sharded-indexed (the same candidate
  path with its postings sharded over a device mesh,
  :mod:`repro.distributed.sharded_index`) / the four CPU algorithms.

``PreparedCollection`` duck-types the read surface of ``Collection``
(``tokens`` / ``lengths`` / ``num_sets`` / ``max_len`` / ``row``) **over the
length-sorted view**; drivers that consume it return pairs in the *original*
collection's indices (they remap through ``order``).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, Optional, Tuple

import numpy as np

from repro.core import bitmap as bm
from repro.core import bounds
from repro.core.collection import Collection
from repro.core.constants import BITMAP_COMBINED, JACCARD
from repro.core.filters import BitmapFilter
from repro.core.plan import JoinPlan, JoinPlanner, CPU_DRIVERS


class PreparedCollection:
    """Build-once join artifacts for one collection.

    Construction (via :func:`prepare`) performs the only eager step — the
    stable length sort every driver needs.  Everything else (device arrays,
    packed bitmap words per ``(b, method, mix)``, integer length windows per
    ``(sim, tau)``, CPU prefix indexes per ``(sim, tau, ell)``) is built on
    first use and cached; ``builds`` counts each build so callers can assert
    amortization (see ``benchmarks/bench_engine.py``).
    """

    def __init__(self, source: Collection):
        order = np.argsort(source.lengths, kind="stable")
        inverse = np.empty_like(order)
        inverse[order] = np.arange(len(order))
        self.source = source
        self.order = order          # sorted index -> original index
        self.inverse = inverse      # original index -> sorted index
        self.tokens = source.tokens[order]    # length-sorted view (numpy)
        self.lengths = source.lengths[order]
        # Every cached artifact below is derived from the source arrays; an
        # in-place edit after prepare() would silently serve stale sorts and
        # bitmaps.  Seal both the source and the sorted copies — growth goes
        # through new store segments (repro.store), never mutation.
        for arr in (source.tokens, source.lengths, self.tokens, self.lengths):
            arr.flags.writeable = False
        self.builds: Dict[str, int] = {
            "sort": 1, "bitmap": 0, "window": 0, "prefix_index": 0,
            "postings": 0, "sharded_postings": 0}
        self._device: Optional[Tuple] = None          # (tokens, lengths) jnp
        self._words: Dict[Tuple[int, str, bool], object] = {}
        self._words_np: Dict[Tuple[int, str, bool], np.ndarray] = {}
        self._windows: Dict[Tuple[str, float], Tuple] = {}
        self._prefix: Dict[Tuple[str, float, int], dict] = {}
        self._postings: Dict[Tuple[str, float, int], object] = {}
        self._sharded_postings: Dict[Tuple[str, float, int, int], object] = {}
        self._sorted_collection: Optional[Collection] = None

    # -- Collection duck-typing (over the length-sorted view) ---------------

    @property
    def num_sets(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def max_len(self) -> int:
        return int(self.tokens.shape[1])

    def __len__(self) -> int:
        return self.num_sets

    def row(self, i: int) -> np.ndarray:
        return self.tokens[i, : self.lengths[i]]

    @property
    def sorted_collection(self) -> Collection:
        if self._sorted_collection is None:
            self._sorted_collection = Collection(tokens=self.tokens,
                                                 lengths=self.lengths)
        return self._sorted_collection

    # -- cached artifacts ----------------------------------------------------

    def device_arrays(self):
        """(tokens, lengths) as device (jnp) arrays, cached."""
        if self._device is None:
            import jax.numpy as jnp
            self._device = (jnp.asarray(self.tokens), jnp.asarray(self.lengths))
        return self._device

    def bitmap_words(self, b: int, method: str, *, mix: bool = False,
                     tau: Optional[float] = None):
        """Packed ``uint32[N, b//32]`` words over the sorted view, cached per
        ``(b, resolved method, mix)``.  ``method='combined'`` needs ``tau`` to
        resolve via Algorithm 6."""
        if method == BITMAP_COMBINED:
            if tau is None:
                raise ValueError("combined method needs tau to resolve")
            method = bm.choose_method(float(tau), b)
        key = (int(b), method, bool(mix))
        if key not in self._words:
            tokens, lengths = self.device_arrays()
            self._words[key] = bm.generate_bitmaps(tokens, lengths, b,
                                                   method=method, mix=mix)
            self.builds["bitmap"] += 1
        return self._words[key]

    def bitmap_words_np(self, b: int, method: str, *, mix: bool = False,
                        tau: Optional[float] = None) -> np.ndarray:
        """Numpy twin of :meth:`bitmap_words` (for the CPU ``BitmapFilter``)."""
        if method == BITMAP_COMBINED:
            if tau is None:
                raise ValueError("combined method needs tau to resolve")
            method = bm.choose_method(float(tau), b)
        key = (int(b), method, bool(mix))
        if key not in self._words_np:
            self._words_np[key] = np.asarray(
                self.bitmap_words(b, method, mix=mix))
        return self._words_np[key]

    def length_window_int(self, sim: str, tau: float):
        """Integer-exact Table 2 windows for every sorted row, cached per
        ``(sim, tau)``.  Returns ``(lo_np, hi_np, lo_jnp, hi_jnp)``."""
        key = (sim, float(tau))
        if key not in self._windows:
            import jax.numpy as jnp
            lo, hi = bounds.length_window_int(sim, tau, self.lengths)
            self._windows[key] = (lo, hi, jnp.asarray(lo), jnp.asarray(hi))
            self.builds["window"] += 1
        return self._windows[key]

    def prefix_index(self, sim: str, tau: float, ell: int = 1) -> dict:
        """Cached ℓ-prefix inverted index over the sorted view (the CPU
        algorithms' build artifact)."""
        key = (sim, float(tau), int(ell))
        if key not in self._prefix:
            from repro.core import cpu_algos
            self._prefix[key] = cpu_algos._build_prefix_index(
                self.sorted_collection, sim, tau, ell=ell)
            self.builds["prefix_index"] += 1
        return self._prefix[key]

    def postings(self, sim: str, tau: float, ell: int = 1):
        """Cached CSR ℓ-prefix postings index over the sorted view (the
        ``"indexed"`` driver's build artifact — the device twin of
        :meth:`prefix_index`), built at most once per ``(sim, tau, ell)``."""
        key = (sim, float(tau), int(ell))
        if key not in self._postings:
            from repro.index.postings import build_postings
            self._postings[key] = build_postings(self, sim, tau, ell=ell)
            self.builds["postings"] += 1
        return self._postings[key]

    def sharded_postings(self, sim: str, tau: float, ell: int = 1,
                         n_shards: int = 1):
        """Cached token-slab partition of :meth:`postings` (the
        ``"sharded-indexed"`` driver's build artifact), built at most once
        per ``(sim, tau, ell, n_shards)``; the underlying CSR index is
        shared with (and cached by) the single-device driver."""
        key = (sim, float(tau), int(ell), int(n_shards))
        if key not in self._sharded_postings:
            from repro.index.postings import partition_postings
            self._sharded_postings[key] = partition_postings(
                self.postings(sim, tau, ell), n_shards)
            self.builds["sharded_postings"] += 1
        return self._sharded_postings[key]

    def build_counts(self) -> Dict[str, int]:
        """A copy of the build counters
        (sort/bitmap/window/prefix_index/postings/sharded_postings)."""
        return dict(self.builds)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"PreparedCollection(n={self.num_sets}, max_len={self.max_len}, "
                f"builds={self.builds})")


def prepare(col: Collection | PreparedCollection) -> PreparedCollection:
    """Build the reusable join artifact for ``col`` (idempotent)."""
    if isinstance(col, PreparedCollection):
        return col
    return PreparedCollection(col)


def as_prepared(col: Collection | PreparedCollection) -> PreparedCollection:
    """Alias of :func:`prepare`; reads better at driver entry points."""
    return prepare(col)


def prepared_bitmap_filter(
    prep_r: PreparedCollection,
    prep_s: Optional[PreparedCollection] = None,
    *,
    sim: str,
    tau: float,
    b: int = 64,
    method: str = BITMAP_COMBINED,
    mix: bool = False,
    use_cutoff: bool = True,
) -> BitmapFilter:
    """A :class:`~repro.core.filters.BitmapFilter` over prepared collections.

    Reuses the prepared words (no bitmap regeneration); index side R, probe
    side S (self-join when ``prep_s`` is omitted).  Indices fed to
    ``prune_mask`` are in the prepared (length-sorted) space — exactly what
    the CPU algorithms use when handed prepared inputs.
    """
    from repro.core import expected

    chosen = bm.choose_method(float(tau), b) if method == BITMAP_COMBINED else method
    words_r = prep_r.bitmap_words_np(b, chosen, mix=mix)
    cutoff = (expected.cutoff_point(chosen, b, float(tau)) if use_cutoff
              else np.iinfo(np.int32).max)
    kw = {}
    if prep_s is not None and prep_s is not prep_r:
        kw = dict(probe_words=prep_s.bitmap_words_np(b, chosen, mix=mix),
                  probe_lengths=prep_s.lengths)
    return BitmapFilter(words=words_r, lengths=prep_r.lengths, sim=sim,
                        tau=tau, b=b, cutoff=int(cutoff), method=chosen, **kw)


# ---------------------------------------------------------------------------
# JoinEngine: prepare R once, stream probe batches against it
# ---------------------------------------------------------------------------

def _as_store(corpus):
    """``corpus`` if it is a :class:`repro.store.CorpusStore`, else None.
    Imported lazily — :mod:`repro.store` layers *over* this module."""
    if type(corpus).__name__ != "CorpusStore":
        return None
    from repro.store.store import CorpusStore
    return corpus if isinstance(corpus, CorpusStore) else None


@dataclasses.dataclass
class ProbeResult:
    pairs: np.ndarray       # int64[K, 2] (corpus_index, batch_index)
    stats: "object"         # JoinStats for this batch


class JoinEngine:
    """The serving shape: one prepared corpus, many probe batches.

    ``JoinEngine(corpus, sim, tau)`` prepares R once (length sort now; bitmap
    words / windows on the first probe) and resolves a
    :class:`~repro.core.plan.JoinPlan`.  Each :meth:`probe` call joins one
    batch of S against the prepared corpus and returns ``(pairs, JoinStats)``
    with pairs as ``(corpus_index, batch_index)`` in original indices.  The
    corpus-side artifacts are reused across probes — asserted by build
    counters in ``benchmarks/bench_engine.py`` and ``scripts/check.sh``.

    Pass ``mesh=``/``axis=`` to execute a ``ring`` or ``sharded-indexed``
    plan on a real mesh; without one, a ring plan falls back to the blocked
    driver and a sharded-indexed plan to its single-device twin ``indexed``
    (both recorded in ``fallbacks``).

    The corpus may also be a :class:`repro.store.CorpusStore` — the engine
    then adopts the store's plan/sim/tau/mesh and every probe / self-join
    runs the store's segment-union join (base ∪ deltas), so an appendable
    corpus drops in wherever a frozen prepared corpus did.  ``prepared``
    reads through to the store's live base segment across compactions.
    """

    #: Default bound on the per-probe ``JoinStats`` history.  A long-lived
    #: session probes millions of times; an unbounded list was a slow leak.
    HISTORY_LIMIT = 1024

    def __init__(self, corpus: Collection | PreparedCollection,
                 sim: str = JACCARD, tau: float = 0.8, *,
                 plan: Optional[JoinPlan] = None,
                 planner: Optional[JoinPlanner] = None,
                 expected_batch: Optional[int] = None,
                 mesh=None, axis=None,
                 history_limit: Optional[int] = None):
        self.store = _as_store(corpus)
        self._planner = planner or JoinPlanner()
        if self.store is not None:
            store = self.store
            if (sim, float(tau)) not in ((store.sim, store.tau),
                                         (JACCARD, 0.8)):
                raise ValueError(
                    f"engine asked for (sim={sim}, tau={tau}) but the store "
                    f"is (sim={store.sim}, tau={store.tau})")
            if plan is not None and plan != store.plan:
                raise ValueError(
                    "engine plan conflicts with the store's plan; the store "
                    "pins one plan for every segment join")
            self._prepared = store.base.prepared
            self.sim = store.sim
            self.tau = store.tau
            self.plan = store.plan
            self._auto_planned = False
            self.mesh = store.mesh
            self.axis = store.axis
        else:
            self._prepared = prepare(corpus)
            self.sim = sim
            self.tau = float(tau)
            self._auto_planned = plan is None
            if plan is None:
                plan = self._planner.plan(sim, tau,
                                          n_r=self._prepared.num_sets,
                                          n_s=expected_batch)
            self.plan = plan
            self.mesh = mesh
            self.axis = axis
        self.probes = 0
        if history_limit is None:
            history_limit = self.HISTORY_LIMIT
        # Bounded: keeps the newest `history_limit` JoinStats.  The rollup
        # in stats_summary() accumulates over *all* probes regardless.
        self.history: Deque[object] = collections.deque(maxlen=history_limit)
        self.fallbacks: list = []
        self._totals: Dict[str, int] = collections.defaultdict(int)

    @property
    def prepared(self) -> PreparedCollection:
        """The corpus-side artifact: the store's *live* base segment in
        store mode (compaction swaps it; this property never goes stale),
        else the prepared corpus the engine was built on."""
        if self.store is not None:
            return self.store.base.prepared
        return self._prepared

    def attach_store(self, store) -> None:
        """Upgrade a frozen-corpus engine in place to serve ``store``
        (whose base must be this engine's prepared corpus under the same
        plan).  History, fallbacks and the lifetime rollup carry over —
        this is how a resident session absorbs its first ``append()``
        without resetting observability."""
        if store.base.prepared is not self._prepared:
            raise ValueError(
                "store's base segment is not this engine's prepared corpus")
        if (store.sim, store.tau) != (self.sim, self.tau):
            raise ValueError(
                f"store is (sim={store.sim}, tau={store.tau}) but the engine "
                f"serves (sim={self.sim}, tau={self.tau})")
        if store.plan != self.plan:
            raise ValueError("store plan differs from the engine's plan")
        self.store = store
        self._auto_planned = False

    # -- public API ----------------------------------------------------------

    def probe(self, batch: Collection | PreparedCollection, *,
              return_stats: bool = True):
        """Join one batch of S against the prepared corpus.

        Returns ``(pairs, stats)`` (or just pairs with
        ``return_stats=False``); pairs are ``(corpus_index, batch_index)``
        int64 in the original index spaces of both collections.  Batches are
        prepared lazily, only by the drivers that use prepared artifacts
        (blocked / ring / CPU); pass an already-prepared batch to reuse its
        caches across repeated probes.
        """
        pairs, stats = self._execute(batch)
        self.record_probe(stats)
        return (pairs, stats) if return_stats else pairs

    def record_probe(self, stats) -> None:
        """Account one probe's :class:`~repro.core.join.JoinStats`: bump the
        probe counter, append to the bounded history and fold the counters
        into the lifetime rollup.  Called by :meth:`probe` and by the
        serving layer (:mod:`repro.serve`) for coalesced probes it executes
        outside this engine."""
        self.probes += 1
        self.history.append(stats)
        for field in ("total_pairs", "blocks_total", "blocks_skipped",
                      "candidates", "verified_true", "overflow_blocks",
                      "candidates_generated", "postings_expanded"):
            self._totals[field] += getattr(stats, field, 0)

    def stats_summary(self) -> Dict[str, object]:
        """Lifetime rollup over every probe (not just the bounded history):
        summed funnel counters plus derived ratios — the observability
        surface a resident session reports instead of the raw per-probe
        list."""
        t = dict(self._totals)
        total = t.get("total_pairs", 0)
        cand = t.get("candidates", 0)
        return {
            "probes": self.probes,
            "history_len": len(self.history),
            "history_limit": self.history.maxlen,
            "fallbacks": len(self.fallbacks),
            **t,
            "filter_ratio": (1.0 - cand / total) if total else 0.0,
            "precision": (t.get("verified_true", 0) / cand) if cand else 1.0,
        }

    def self_join(self, *, return_stats: bool = False):
        """The corpus joined against itself under this engine's plan."""
        pairs, stats = self._execute(None)
        return (pairs, stats) if return_stats else pairs

    # -- execution -----------------------------------------------------------

    def _execute(self, batch):
        from repro.core import join as join_mod

        if self.store is not None:
            # Segment-union join: the store runs base ∪ per-delta joins
            # through its own per-segment engines (explicit plan, no auto
            # fallbacks) and sums the funnel counters.
            if batch is None:
                return self.store.self_join(return_stats=True)
            return self.store.probe(batch, return_stats=True)

        plan = self.plan
        driver = plan.driver
        if driver == "ring" and self.mesh is None:
            self.fallbacks.append("ring plan without a mesh -> blocked")
            driver = "blocked"
        if driver == "sharded-indexed" and self.mesh is None:
            self.fallbacks.append(
                "sharded-indexed plan without a mesh -> indexed")
            driver = "indexed"
        if (driver == "naive" and self._auto_planned and batch is not None):
            # The auto-planner chose 'naive' from the corpus size alone (the
            # batch size was unknown at plan time); a large batch would make
            # the dense oracle quadratic, so re-check against the planner's
            # own threshold with the real batch in hand.
            cells = self.prepared.num_sets * batch.num_sets
            if cells > self._planner.naive_cells:
                self.fallbacks.append(
                    f"naive plan but this batch gives {cells} cells -> blocked")
                driver = "blocked"

        if driver == "naive":
            # naive_join consumes raw collections — no batch preparation.
            pairs = join_mod.naive_join(self.prepared, batch, self.sim, self.tau)
            n = len(pairs)
            stats = join_mod.JoinStats(total_pairs=n, candidates=n,
                                       verified_true=n,
                                       candidates_generated=n)
            return pairs, stats

        if driver == "blocked":
            return join_mod.blocked_bitmap_join(
                self.prepared, batch, self.sim, self.tau,
                b=plan.b, method=plan.method, mix=plan.mix, block=plan.block,
                impl=plan.impl, use_cutoff=plan.use_cutoff,
                compaction=plan.compaction, capacity=plan.capacity,
                return_stats=True)

        prep_s = None if batch is None else prepare(batch)
        if driver == "indexed":
            from repro.index.candidates import indexed_join_prepared
            return indexed_join_prepared(
                self.prepared, prep_s, sim=self.sim, tau=self.tau,
                b=plan.b, method=plan.method, mix=plan.mix, ell=plan.ell,
                probe_block=plan.block, impl=plan.impl,
                use_cutoff=plan.use_cutoff, capacity=plan.capacity,
                return_stats=True)

        if driver == "sharded-indexed":
            from repro.distributed.sharded_index import (
                sharded_indexed_join_prepared)
            # The driver sums the per-shard funnel counters into the
            # returned JoinStats (the shard-map step emits one counter row
            # per device), so probe() reports the same funnel as "indexed".
            return sharded_indexed_join_prepared(
                self.prepared, prep_s, mesh=self.mesh, axis=self.axis,
                sim=self.sim, tau=self.tau, b=plan.b, method=plan.method,
                mix=plan.mix, ell=plan.ell, probe_block=plan.block,
                impl=plan.impl, use_cutoff=plan.use_cutoff,
                capacity=plan.capacity, return_stats=True)

        if driver == "ring":
            pairs, counters, _overflow = join_mod.ring_join_prepared(
                self.prepared, prep_s, mesh=self.mesh, axis=self.axis,
                sim=self.sim, tau=self.tau, b=plan.b, method=plan.method,
                mix=plan.mix, use_cutoff=plan.use_cutoff, impl=plan.impl,
                capacity_per_step=plan.capacity, return_stats=True)
            # The ring sweep applies no length window: every pair of
            # non-empty sets is bitmap-evaluated exactly once (i < j for a
            # self-join).  total_pairs is that evaluated-grid size, so
            # filter_ratio reports the bitmap's pruning over it.
            nnz_r = int((self.prepared.lengths > 0).sum())
            if prep_s is None:
                total = nnz_r * (nnz_r - 1) // 2
            else:
                total = nnz_r * int((prep_s.lengths > 0).sum())
            stats = join_mod.JoinStats(
                total_pairs=total,
                candidates=int(counters[:, 0].sum()),
                verified_true=len(pairs),
                candidates_generated=total)
            return pairs, stats

        if driver in CPU_DRIVERS:
            from repro.core import cpu_algos
            bf = prepared_bitmap_filter(
                self.prepared, prep_s, sim=self.sim, tau=self.tau, b=plan.b,
                method=plan.method, mix=plan.mix, use_cutoff=plan.use_cutoff)
            astats = cpu_algos.AlgoStats()
            algo = cpu_algos.ALGORITHMS[driver]
            pairs = algo(self.prepared, prep_s, self.sim, self.tau,
                         bitmap=bf, stats=astats)
            stats = join_mod.JoinStats(
                total_pairs=astats.candidates,
                candidates=astats.candidates - astats.bitmap_pruned,
                verified_true=astats.results,
                candidates_generated=astats.candidates)
            return pairs, stats

        raise ValueError(f"unknown driver {driver!r}")  # pragma: no cover
