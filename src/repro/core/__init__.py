"""The paper's primary contribution: the Bitmap Filter and exact set
similarity joins built on it (Sandes, Teodoro & Melo, 2017).

Public surface:

* :mod:`repro.core.bitmap` — Bitmap-Set / Xor / Next / Combined generation.
* :mod:`repro.core.bounds` — Eq. 2 upper bound + Table 1/2 conversions.
* :mod:`repro.core.expected` — Eq. 4-6 expected bounds, cutoff ω(b, τ).
* :mod:`repro.core.join` — naive oracle, blocked device join, ring join.
* :mod:`repro.core.cpu_algos` — faithful AllPairs/PPJoin/GroupJoin/AdaptJoin.
* :mod:`repro.core.engine` — build-once :class:`PreparedCollection` artifacts
  and the batched-probe :class:`JoinEngine`.
* :mod:`repro.core.plan` — :class:`JoinPlanner` resolving workloads into
  explicit :class:`JoinPlan` configurations.

The device-resident inverted prefix-index subsystem (CSR postings + the
``"indexed"`` sub-quadratic driver) lives in :mod:`repro.index`.
"""

from repro.core.collection import (
    Collection,
    from_lists,
    pad_collection,
    preprocess,
    preprocess_rs,
)
from repro.core.engine import (
    JoinEngine,
    PreparedCollection,
    as_prepared,
    prepare,
    prepared_bitmap_filter,
)
from repro.core.plan import JoinPlan, JoinPlanner
from repro.core.constants import (
    BITMAP_COMBINED,
    BITMAP_METHODS,
    BITMAP_NEXT,
    BITMAP_SET,
    BITMAP_XOR,
    COSINE,
    DICE,
    JACCARD,
    OVERLAP,
    PAD_TOKEN,
    SIM_FUNCTIONS,
)
