"""Compile a prepared collection's ℓ-prefix inverted index into flat CSR
device arrays.

The CPU algorithms' build artifact (``cpu_algos._build_prefix_index``) is a
``dict[token] -> [(set_id, position), ...]`` — unbeatable for a Python probe
loop, useless on an accelerator.  This module compiles the *same* index into
the device-friendly form the indexed join driver consumes:

* tokens are remapped to **dense frequency-ordered ids** (id 0 = rarest,
  ties broken by token value) — the order that makes prefix postings lists
  short where probes are frequent;
* postings are laid out **CSR**: ``starts[tid] : starts[tid + 1]`` spans
  token ``tid``'s entries in the flat ``post_set`` / ``post_pos`` arrays;
* within a token's list, entries are **sorted by set id == by length** (the
  prepared collection is length-sorted), the invariant the length filter's
  early-outs rely on everywhere else in the repo — here it powers the
  ``post_key`` composite ``(token id, length)`` key, globally
  non-decreasing, so one vectorized ``searchsorted`` narrows every probe's
  lookup to the admissible length window *before* expansion (the device
  analogue of the CPU algorithms' sorted-list break/continue, and what
  keeps expansion volume near the real candidate count on skewed data);
* ``post_len`` caches ``lengths[post_set]`` so the entry filter needs no
  extra gather;
* probe-side lookup is a value-ordered ``vocab`` + ``searchsorted`` (rows in
  a :class:`~repro.core.collection.Collection` are token-value sorted, so
  the value order *is* the shared global token order prefix-filter
  correctness requires across two collections).

Instances are cached on the :class:`~repro.core.engine.PreparedCollection`
per ``(sim, tau, ell)`` — see ``PreparedCollection.postings`` — with a
``builds["postings"]`` counter proving reuse.

For multi-device meshes, :func:`partition_postings` re-cuts a compiled index
into :class:`ShardedPostings`: contiguous *token-id slabs* (dense
frequency-ordered ids), one per device, balanced by postings volume.  Token
slabs — not set-id ranges — are the unit of sharding because the composite
``post_key`` stays locally searchable inside each slab: every device runs
the *same* windowed ``searchsorted`` lookup against its slab and sees count
0 for tokens it does not own, so the per-shard expansions partition the
global expansion exactly.  :func:`shard_expansion_counts` is the host
(int64-exact) per-shard count prepass the ``"sharded-indexed"`` driver
sizes its per-device capacities from.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core import bounds


@dataclasses.dataclass
class PostingsIndex:
    """Flat CSR ℓ-prefix inverted index over one prepared collection.

    All ids are in the prepared (length-sorted) index space; callers remap
    result pairs through ``prepared.order`` exactly like every other driver.
    """

    sim: str
    tau: float
    ell: int
    max_len: int            # padded row width L; post_key scale is L + 1
    vocab: np.ndarray       # int32[V] distinct prefix tokens, ascending value
    vocab_tid: np.ndarray   # int32[V] dense frequency-ordered id of vocab[k]
    starts: np.ndarray      # int32[V + 1] CSR row starts over dense ids
    post_set: np.ndarray    # int32[P] set id (sorted space), ascending per row
    post_pos: np.ndarray    # int32[P] token position inside the set row
    post_len: np.ndarray    # int32[P] == lengths[post_set]
    post_key: np.ndarray    # int32[P] tid * (L + 1) + post_len, non-decreasing
    prefix_len: np.ndarray  # int32[N] ℓ-prefix length per sorted row
    _device: Optional[Tuple] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def num_tokens(self) -> int:
        return int(self.vocab.shape[0])

    @property
    def num_postings(self) -> int:
        return int(self.post_set.shape[0])

    def device_arrays(self):
        """(vocab, vocab_tid, post_set, post_pos, post_len, post_key) as jnp
        device arrays, cached."""
        if self._device is None:
            import jax.numpy as jnp
            self._device = tuple(jnp.asarray(a) for a in (
                self.vocab, self.vocab_tid,
                self.post_set, self.post_pos, self.post_len, self.post_key))
        return self._device

    def as_dict(self) -> dict:
        """token -> [(set_id, position), ...] — the CPU index shape, for
        differential tests against ``cpu_algos._build_prefix_index``."""
        out = {}
        for k in range(self.num_tokens):
            tid = int(self.vocab_tid[k])
            sl = slice(int(self.starts[tid]), int(self.starts[tid + 1]))
            out[int(self.vocab[k])] = list(
                zip(self.post_set[sl].tolist(), self.post_pos[sl].tolist()))
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"PostingsIndex(sim={self.sim}, tau={self.tau}, "
                f"ell={self.ell}, tokens={self.num_tokens}, "
                f"postings={self.num_postings})")


def build_postings(prep, sim: str, tau: float, ell: int = 1) -> PostingsIndex:
    """Compile the ℓ-prefix inverted index of a prepared collection.

    Fully vectorized (no per-set Python loop): prefix lengths come from
    Table 2 (:func:`repro.core.bounds.prefix_length_ell`), the flat
    ``(set, pos)`` expansion from a cumsum/searchsorted, and the CSR layout
    from one stable argsort by dense token id (stability preserves the
    ascending-set-id order inside each postings list).
    """
    lengths = np.asarray(prep.lengths, dtype=np.int64)
    max_len = int(prep.max_len)
    n = int(lengths.shape[0])
    p = np.zeros(n, dtype=np.int64)
    nz = lengths > 0
    if nz.any():
        p[nz] = bounds.prefix_length_ell(sim, tau, lengths[nz], ell)
    total = int(p.sum())
    if total == 0:
        empty32 = np.zeros(0, dtype=np.int32)
        return PostingsIndex(
            sim=sim, tau=float(tau), ell=int(ell), max_len=max_len,
            vocab=empty32, vocab_tid=empty32,
            starts=np.zeros(1, dtype=np.int32),
            post_set=empty32, post_pos=empty32, post_len=empty32,
            post_key=empty32, prefix_len=p.astype(np.int32))

    ends = np.cumsum(p)
    flat = np.arange(total, dtype=np.int64)
    set_id = np.searchsorted(ends, flat, side="right")
    pos = flat - (ends[set_id] - p[set_id])
    toks = np.asarray(prep.tokens)[set_id, pos].astype(np.int64)

    vocab, inverse, counts = np.unique(toks, return_inverse=True,
                                       return_counts=True)
    # Dense frequency-ordered ids: rarest first, ties by ascending value.
    order = np.lexsort((vocab, counts))
    rank = np.empty(len(vocab), dtype=np.int64)
    rank[order] = np.arange(len(vocab))
    tid = rank[inverse]

    perm = np.argsort(tid, kind="stable")  # keeps per-token set-id order
    starts = np.zeros(len(vocab) + 1, dtype=np.int64)
    starts[1:] = np.cumsum(np.bincount(tid, minlength=len(vocab)))
    post_set = set_id[perm].astype(np.int32)
    post_len = lengths[post_set].astype(np.int64)
    # Composite (token id, length) key: per-token runs are length-ascending
    # (set ids are length-sorted), so the key is globally non-decreasing and
    # one searchsorted narrows any probe's lookup to its length window.
    if len(vocab) * (max_len + 1) > np.iinfo(np.int32).max:
        raise ValueError(
            f"postings key space {len(vocab)} tokens x (max_len={max_len} + 1)"
            f" overflows int32; shrink the vocabulary or pad width")
    post_key = tid[perm] * (max_len + 1) + post_len
    return PostingsIndex(
        sim=sim, tau=float(tau), ell=int(ell), max_len=max_len,
        vocab=vocab.astype(np.int32),
        vocab_tid=rank.astype(np.int32),
        starts=starts.astype(np.int32),
        post_set=post_set,
        post_pos=pos[perm].astype(np.int32),
        post_len=post_len.astype(np.int32),
        post_key=post_key.astype(np.int32),
        prefix_len=p.astype(np.int32))


# ---------------------------------------------------------------------------
# Token-slab partitioning (the "sharded-indexed" driver's build artifact)
# ---------------------------------------------------------------------------

# Padding sentinel for per-slab post_key tails.  build_postings guarantees
# every real key satisfies key <= num_tokens * (max_len + 1) - 1 < INT32_MAX
# (it raises when the key space would reach INT32_MAX), and the windowed
# lookup's upper probe is num_tokens * scale - 1 at most, so sentinel slots
# can never fall inside a searchsorted range.
_KEY_SENTINEL = np.int32(np.iinfo(np.int32).max)


@dataclasses.dataclass
class ShardedPostings:
    """A :class:`PostingsIndex` re-cut into contiguous token-id slabs.

    ``post_*[k]`` hold shard ``k``'s postings (padded to a common width with
    ``_KEY_SENTINEL`` keys, so the same windowed ``searchsorted`` lookup
    works unchanged per slab); ``slab_tid[k] : slab_tid[k + 1]`` is the dense
    token-id range shard ``k`` owns, chosen so postings volume — not token
    count — balances across shards.  ``vocab`` / ``vocab_tid`` stay global
    (replicated): probe-side token lookup is identical on every device.
    """

    base: PostingsIndex
    n_shards: int
    slab_tid: np.ndarray    # int64[n_shards + 1] dense-token-id boundaries
    counts: np.ndarray      # int64[n_shards] real postings per slab
    post_set: np.ndarray    # int32[n_shards, pmax]
    post_pos: np.ndarray    # int32[n_shards, pmax]
    post_len: np.ndarray    # int32[n_shards, pmax]
    post_key: np.ndarray    # int32[n_shards, pmax]; sentinel-padded tails
    _device: Optional[Tuple] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def slab_width(self) -> int:
        return int(self.post_set.shape[1])

    def device_arrays(self):
        """(post_set, post_pos, post_len, post_key) stacked per shard as jnp
        device arrays, cached (the shard_map inputs with the sharded spec)."""
        if self._device is None:
            import jax.numpy as jnp
            self._device = tuple(jnp.asarray(a) for a in (
                self.post_set, self.post_pos, self.post_len, self.post_key))
        return self._device

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"ShardedPostings(n_shards={self.n_shards}, "
                f"width={self.slab_width}, counts={self.counts.tolist()})")


def partition_postings(post: PostingsIndex, n_shards: int) -> ShardedPostings:
    """Cut a compiled postings index into ``n_shards`` contiguous token slabs.

    Boundaries come from the CSR row offsets: slab ``k`` starts at the first
    token whose cumulative postings count reaches ``k / n_shards`` of the
    total, so slabs are balanced by *postings volume* (a hot token still
    lands wholly in one slab — tokens are atomic; the per-shard count
    prepass and overflow escalation absorb that skew, tested by the
    hot-slab multidevice test).
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    cum = post.starts.astype(np.int64)          # int32[V+1] row offsets
    total = int(post.num_postings)
    targets = (total * np.arange(n_shards + 1, dtype=np.int64)) // n_shards
    slab_tid = np.searchsorted(cum, targets, side="left").astype(np.int64)
    slab_tid[0] = 0
    slab_tid[-1] = post.num_tokens
    slab_tid = np.maximum.accumulate(slab_tid)
    slab_post = cum[slab_tid]
    counts = np.diff(slab_post)
    pmax = max(int(counts.max(initial=0)), 1)

    post_set = np.zeros((n_shards, pmax), dtype=np.int32)
    post_pos = np.zeros((n_shards, pmax), dtype=np.int32)
    post_len = np.zeros((n_shards, pmax), dtype=np.int32)
    post_key = np.full((n_shards, pmax), _KEY_SENTINEL, dtype=np.int32)
    for k in range(n_shards):
        sl = slice(int(slab_post[k]), int(slab_post[k + 1]))
        w = int(counts[k])
        post_set[k, :w] = post.post_set[sl]
        post_pos[k, :w] = post.post_pos[sl]
        post_len[k, :w] = post.post_len[sl]
        post_key[k, :w] = post.post_key[sl]
    return ShardedPostings(
        base=post, n_shards=int(n_shards), slab_tid=slab_tid,
        counts=counts, post_set=post_set, post_pos=post_pos,
        post_len=post_len, post_key=post_key)


def lookup_counts_host(post: PostingsIndex, tokens_np, ps_np, lo_np, hi_np,
                       lp: int):
    """Host (int64-exact) twin of the device windowed lookup.

    Returns ``(cnt, tid, valid)``, each ``[C, lp]``: the window-surviving
    postings count, the dense token id, and the lookup-validity mask per
    ``(probe, prefix position)``.  Shared by the total count prepass
    (``candidates._expansion_count_host``) and the per-shard one
    (:func:`shard_expansion_counts`); both size capacities *and* guard the
    fused step — a pathological expansion is detected before any device
    buffer is allocated.
    """
    c = int(np.asarray(tokens_np).shape[0])
    if post.num_tokens == 0 or lp == 0:
        z = np.zeros((c, max(lp, 1)), dtype=np.int64)
        return z, z.copy(), np.zeros_like(z, dtype=bool)
    scale = post.max_len + 1
    ptoks = np.asarray(tokens_np)[:, :lp].astype(np.int64)
    j = np.clip(np.searchsorted(post.vocab, ptoks), 0, post.num_tokens - 1)
    found = post.vocab[j].astype(np.int64) == ptoks
    tid = np.where(found, post.vocab_tid[j], 0).astype(np.int64)
    valid = found & (np.arange(lp)[None, :] < np.asarray(ps_np)[:, None])
    base = tid * scale
    lo_c = np.clip(np.asarray(lo_np).astype(np.int64), 0, scale - 1)[:, None]
    hi_c = np.clip(np.asarray(hi_np).astype(np.int64), 0, scale - 1)[:, None]
    a = np.searchsorted(post.post_key, base + lo_c, side="left")
    b = np.searchsorted(post.post_key, base + hi_c, side="right")
    cnt = np.where(valid, np.maximum(b - a, 0), 0).astype(np.int64)
    return cnt, tid, valid


def shard_expansion_counts(sharded: ShardedPostings, tokens_np, ps_np,
                           lo_np, hi_np, lp: int) -> np.ndarray:
    """Per-shard count prepass: how many window-surviving postings entries
    this probe chunk expands to *on each token slab* (``int64[n_shards]``).

    Token slabs are disjoint, so these partition the single-device count
    exactly: ``shard_expansion_counts(...).sum()`` equals the unsharded
    prepass total — asserted by the multidevice shard-count-invariance test.
    """
    cnt, tid, valid = lookup_counts_host(
        sharded.base, tokens_np, ps_np, lo_np, hi_np, lp)
    owner = np.clip(
        np.searchsorted(sharded.slab_tid, tid, side="right") - 1,
        0, sharded.n_shards - 1)
    out = np.zeros(sharded.n_shards, dtype=np.int64)
    np.add.at(out, owner[valid], cnt[valid])
    return out
