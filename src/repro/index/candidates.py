"""The ``"indexed"`` join driver: sub-quadratic, index-driven candidate
generation feeding the bitmap filter + fused verification.

Every other device driver (``naive``/``blocked``/``ring``) evaluates the
bitmap filter over the (windowed) O(|R|·|S|) grid; at paper scale that grid
— not the per-pair cost — is the wall.  The CPU algorithms avoid it with
prefix-filter inverted indexes; this driver brings the same asymptotics to
the accelerator stack:

1. **Expand** — for each probe batch, look up the probe prefix tokens in the
   CSR postings index (:mod:`repro.index.postings`) and expand the matching
   lists into a flat entry stream, sized by a count prepass (the capacity
   pattern of ``kernels/compaction.py``).
2. **Filter** — admit entries through the classic filters
   (:func:`repro.kernels.ops.entry_filter`: integer length window,
   positional bound, self-join triangle) on device.
3. **Deduplicate** — sort the surviving ``(probe, set)`` keys and keep
   unique ones, compacted to a fixed ``(cap, 2)`` candidate buffer.
4. **Verify** — the PR 2 fused step, but over the candidate *list*: pairwise
   bitmap verdict (:func:`repro.kernels.ops.pair_verdict`) → exact
   ``searchsorted`` verification → compaction down to verified pairs.

Steps 1-4 run inside one jit per probe chunk; only the compacted pair
buffer and four counters cross to the host.  A chunk whose expansion
exceeds an explicitly forced ``capacity`` escalates to a dense
grid-over-chunk fallback (flagged in ``JoinStats.overflow_blocks``), so the
result is exact for *any* capacity — same contract as the blocked driver.

``JoinStats`` for this driver reports the candidate funnel:
``postings_expanded`` (pre-dedup entries) → ``candidates_generated`` (==
``total_pairs``: deduped pairs the bitmap is evaluated on) → ``candidates``
(after the bitmap) → ``verified_true``.  ``filter_ratio`` therefore measures
the bitmap's pruning over *generated* candidates, and comparing
``candidates_generated`` against the blocked driver's quantifies the
sub-quadratic win (asserted in ``tests/test_indexed_join.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core import bounds, expected, verify
from repro.core.collection import Collection, split_join_args
from repro.core.constants import BITMAP_COMBINED, JACCARD, PAD_TOKEN
from repro.core.engine import PreparedCollection, as_prepared
from repro.core.join import JoinStats, _bucket_capacity
from repro.kernels import ops as kops

_INT32_MAX = np.int32(np.iinfo(np.int32).max)
# Auto-sized chunk buffers are capped here; a chunk whose (exact, host
# int64) expansion count exceeds it escalates to the dense fallback instead
# of allocating multi-GiB device buffers (or wrapping int32 on device).
_MAX_AUTO_CAPACITY = 1 << 26


def _windowed_ranges(vocab, vocab_tid, post_key, probe_tokens, probe_prefix,
                     lo_r, hi_r, lp: int, scale: int):
    """Vocab lookup + window-narrowed CSR ranges per (probe, prefix pos).

    One vectorized ``searchsorted`` against the composite non-decreasing
    ``post_key`` = ``tid * scale + length`` bounds each lookup to postings
    whose set length falls inside the probe's admissible window — the
    device analogue of the CPU algorithms' sorted-list early-outs, and what
    keeps expansion volume near the candidate count on skewed data.

    Returns ``(range_start, count)``, both int32[C, lp] (count 0 where the
    prefix position is invalid or the token is unknown).
    """
    ptoks = probe_tokens[:, :lp]
    j = jnp.clip(jnp.searchsorted(vocab, ptoks), 0, vocab.shape[0] - 1)
    found = vocab[j] == ptoks
    tid = jnp.where(found, vocab_tid[j], 0)
    evalid = found & (jnp.arange(lp)[None, :] < probe_prefix[:, None])
    base = tid * scale
    lo_c = jnp.clip(lo_r, 0, scale - 1)[:, None]
    hi_c = jnp.clip(hi_r, 0, scale - 1)[:, None]
    a = jnp.searchsorted(post_key, base + lo_c, side="left")
    b = jnp.searchsorted(post_key, base + hi_c, side="right")
    cnt = jnp.where(evalid, jnp.maximum(b - a, 0), 0)
    return a.astype(jnp.int32), cnt.astype(jnp.int32)


def _expansion_count_host(post, tokens_np, ps_np, lo_np, hi_np,
                          lp: int, scale: int) -> int:
    """Count prepass on host numpy (int64-exact): total window-surviving
    postings entries this probe chunk expands to.

    Runs the same vocab lookup + composite-key narrowing as the device
    step, but in host int64 — the count both sizes the fused step's
    capacity and guards it: a pathological chunk (hot token × huge window)
    whose expansion would wrap int32 or exhaust device memory is detected
    *before* anything is allocated and escalated to the dense fallback.
    (``scale`` is implied by ``post``; kept for call-site symmetry with the
    device step.)
    """
    from repro.index.postings import lookup_counts_host

    cnt, _tid, valid = lookup_counts_host(
        post, tokens_np, ps_np, lo_np, hi_np, lp)
    return int(cnt[valid].sum())


def expand_and_filter(
    post_set, post_pos, post_len, post_key, vocab, vocab_tid,
    probe_tokens, probe_lengths, probe_prefix, lo_r, hi_r, s0,
    *, sim: str, tau: float, cap: int, lp: int, scale: int, self_join: bool,
    impl: str,
):
    """Traced stage 1: CSR expansion + per-entry admission filters over one
    postings *view* — the full index, or one token slab of a
    :class:`~repro.index.postings.ShardedPostings` (the arrays are
    interchangeable: slab tails carry sentinel keys, so the same windowed
    ``searchsorted`` sees count 0 for tokens the view does not own).

    Returns ``(rr, ss, n_expanded)``: sentinel-keyed entry streams (pruned
    slots hold ``_INT32_MAX``) ready for :func:`dedup_pairs`, plus the exact
    expansion count of this view.
    """
    c = probe_tokens.shape[0]

    # -- expand: window-narrowed CSR lookups per (probe, prefix position) --
    rng_start, cnt2d = _windowed_ranges(
        vocab, vocab_tid, post_key, probe_tokens, probe_prefix, lo_r, hi_r,
        lp, scale)
    rng_flat = rng_start.reshape(-1)
    cnt = cnt2d.reshape(-1)
    seg_end = jnp.cumsum(cnt)
    n_expanded = seg_end[-1]

    g = jnp.arange(cap, dtype=jnp.int32)
    k = jnp.clip(jnp.searchsorted(seg_end, g, side="right"), 0, c * lp - 1)
    in_range = g < n_expanded
    within = g - (seg_end[k] - cnt[k])
    pidx = jnp.clip(rng_flat[k] + within, 0, post_set.shape[0] - 1)
    r_idx = post_set[pidx]
    s_loc = (k // lp).astype(jnp.int32)

    # -- filter: length window + positional bound + triangle, on device ----
    keep = kops.entry_filter(
        post_len[pidx], post_pos[pidx],
        probe_lengths[s_loc], (k % lp).astype(jnp.int32),
        lo_r[s_loc], hi_r[s_loc],
        r_idx, s0 + s_loc, in_range,
        sim=sim, tau=tau, self_join=self_join, impl=impl)

    rr = jnp.where(keep, r_idx, _INT32_MAX)
    ss = jnp.where(keep, s_loc, _INT32_MAX)
    return rr, ss, n_expanded


def dedup_pairs(rr, ss, cap: int):
    """Traced stage 2: lexsort sentinel-keyed ``(probe, set)`` entries, keep
    uniques, compact into a ``cap``-slot buffer.

    ``rr`` / ``ss`` may be any length (a chunk's entry stream, or shard
    buffers gathered across the mesh); pruned/padding slots must hold
    ``_INT32_MAX``.  Returns ``(cand_r, cand_s, n_generated)`` with slots
    ``>= n_generated`` holding ``_INT32_MAX`` again — the output composes
    with itself, which is exactly how the sharded driver re-deduplicates
    the allgathered per-shard buffers.
    """
    # (two int32 sort keys rather than one fused int64 key: x64 stays off)
    order = jnp.lexsort((rr, ss))  # s major, r minor; pruned slots sort last
    sr = rr[order]
    s2 = ss[order]
    uniq = (s2 != _INT32_MAX) & jnp.concatenate(
        [jnp.ones((1,), dtype=bool), (s2[1:] != s2[:-1]) | (sr[1:] != sr[:-1])])
    n_generated = jnp.sum(uniq, dtype=jnp.int32)
    ui = jnp.nonzero(uniq, size=cap, fill_value=0)[0]
    slot_ok = jnp.arange(cap) < n_generated
    cand_r = jnp.where(slot_ok, sr[ui], _INT32_MAX)
    cand_s = jnp.where(slot_ok, s2[ui], _INT32_MAX)
    return cand_r, cand_s, n_generated


def verdict_and_verify(
    tokens_r, lengths_r, words_r, probe_tokens, probe_lengths, probe_words,
    cand_r, cand_s, slot_ok, need_tab, s0,
    *, sim: str, tau: float, cutoff: int, impl: str,
    return_masks: bool = False,
):
    """Traced stage 3: pairwise bitmap verdict → exact overlap verification
    → verified-only compaction, over a compacted candidate buffer (a whole
    chunk's, or one device's slice of the globally deduped list).

    Returns ``(pairs, n_bitmap, n_verified)``; pair slots ``>= n_verified``
    are garbage.  ``return_masks=True`` additionally returns the per-slot
    bitmap-survivor and verified masks (``bool[cap]`` each) — the serving
    layer (:mod:`repro.serve`) segment-sums them per probe row to recover
    per-request funnel counters from a coalesced batch.
    """
    cap = cand_r.shape[0]
    safe_r = jnp.where(slot_ok, cand_r, 0)
    safe_s = jnp.where(slot_ok, cand_s, 0)
    bm_pass = kops.pair_verdict(
        words_r[safe_r], probe_words[safe_s],
        lengths_r[safe_r], probe_lengths[safe_s],
        sim=sim, tau=tau, cutoff=cutoff, impl=impl)
    cand_mask = slot_ok & bm_pass
    n_bitmap = jnp.sum(cand_mask, dtype=jnp.int32)
    o = verify.pairwise_overlap(tokens_r[safe_r], probe_tokens[safe_s])
    # Integer-exact acceptance (min_overlap_table) — identical to the
    # f64 oracle; f32 thresholds are prune-only in this driver too.
    need = bounds.min_overlap_gather(
        sim, need_tab, lengths_r[safe_r], probe_lengths[safe_s])
    ok = cand_mask & (o >= need)
    n_verified = jnp.sum(ok, dtype=jnp.int32)
    vi = jnp.nonzero(ok, size=cap, fill_value=0)[0]
    pairs = jnp.stack([safe_r[vi], safe_s[vi] + s0], axis=1)
    if return_masks:
        return pairs, n_bitmap, n_verified, cand_mask, ok
    return pairs, n_bitmap, n_verified


@functools.partial(
    jax.jit,
    static_argnames=("sim", "tau", "cap", "lp", "scale", "self_join",
                     "cutoff", "impl"),
)
def _indexed_chunk_step(
    tokens_r, lengths_r, words_r,
    vocab, vocab_tid, post_set, post_pos, post_len, post_key,
    probe_tokens, probe_lengths, probe_words, probe_prefix, lo_r, hi_r,
    need_tab, s0,
    *, sim: str, tau: float, cap: int, lp: int, scale: int, self_join: bool,
    cutoff: int, impl: str,
):
    """One fused candidate-generation + verification step for a probe chunk:
    the three traced stages (:func:`expand_and_filter` → :func:`dedup_pairs`
    → :func:`verdict_and_verify`) composed under a single jit.  The sharded
    driver (:mod:`repro.distributed.sharded_index`) composes the *same*
    stages per shard inside ``shard_map`` — one code path, two meshes.

    Expansion, entry filters, sort-dedup, pairwise bitmap verdict and exact
    verification all stay on device; the host receives the compacted
    ``(cap, 2)`` verified-pair buffer plus four scalars.

    Returns ``(pairs, n_expanded, n_generated, n_bitmap, n_verified)``:
    pairs are ``(r_sorted, s_sorted)`` ids (slots ``>= n_verified`` are
    garbage); ``n_expanded > cap`` means the entry stream was truncated and
    the caller must escalate this chunk (it pre-checks via the count
    prepass, so this only happens under an explicitly forced capacity).
    """
    rr, ss, n_expanded = expand_and_filter(
        post_set, post_pos, post_len, post_key, vocab, vocab_tid,
        probe_tokens, probe_lengths, probe_prefix, lo_r, hi_r, s0,
        sim=sim, tau=tau, cap=cap, lp=lp, scale=scale, self_join=self_join,
        impl=impl)
    cand_r, cand_s, n_generated = dedup_pairs(rr, ss, cap)
    slot_ok = jnp.arange(cap) < n_generated
    pairs, n_bitmap, n_verified = verdict_and_verify(
        tokens_r, lengths_r, words_r, probe_tokens, probe_lengths,
        probe_words, cand_r, cand_s, slot_ok, need_tab, s0,
        sim=sim, tau=tau, cutoff=cutoff, impl=impl)
    return pairs, n_expanded, n_generated, n_bitmap, n_verified


def chunk_step_spec(
    prep_r: "PreparedCollection",
    prep_s: "PreparedCollection | None" = None,
    *,
    sim: str = JACCARD,
    tau: float = 0.8,
    b: int = 128,
    method: str = BITMAP_COMBINED,
    mix: bool = False,
    ell: int = 1,
    probe_block: int = 4096,
    impl: str = "auto",
    use_cutoff: bool = True,
):
    """Concrete ``(args, statics)`` for one fused chunk step over the first
    probe chunk — exactly what :func:`indexed_join_prepared` dispatches, but
    reified so callers can ``_indexed_chunk_step.lower(*args, **statics)``
    (roofline/HLO analysis in ``benchmarks/bench_kernels.py``) or time the
    compiled step in isolation.

    Raises ``ValueError`` for a degenerate spec (empty index or zero prefix
    lengths) where the driver would never dispatch the step at all.
    """
    self_join = prep_s is None
    if self_join:
        prep_s = prep_r
    chosen = bm.choose_method(tau, b) if method == BITMAP_COMBINED else method
    cutoff = (expected.cutoff_point(chosen, b, float(tau)) if use_cutoff
              else 1 << 30)
    post = prep_r.postings(sim, tau, ell)
    ps_np, lp = probe_prefix_lengths(prep_s, sim, tau)
    if post.num_postings == 0 or lp == 0:
        raise ValueError("degenerate chunk spec: empty index or prefixes")
    tokens_r, lengths_r = prep_r.device_arrays()
    words_r = prep_r.bitmap_words(b, chosen, mix=mix)
    tokens_s, lengths_s = prep_s.device_arrays()
    words_s = prep_s.bitmap_words(b, chosen, mix=mix)
    lo_np, hi_np, lo_d, hi_d = prep_s.length_window_int(sim, tau)
    csr = post.device_arrays()
    scale = post.max_len + 1
    need_tab = verify.min_overlap_table_dev(
        sim, float(tau), prep_r.max_len, prep_s.max_len)
    cb = min(int(probe_block), prep_s.num_sets)
    n_exp = _expansion_count_host(
        post, prep_s.tokens[:cb], ps_np[:cb], lo_np[:cb], hi_np[:cb],
        lp, scale)
    cap = min(_bucket_capacity(max(n_exp, 1)), prep_r.num_sets * cb * lp)
    ps_d = jnp.asarray(ps_np)
    args = (
        tokens_r, lengths_r, words_r, *csr,
        _pad_chunk(tokens_s[:cb], cb, PAD_TOKEN),
        _pad_chunk(lengths_s[:cb], cb, 0),
        _pad_chunk(words_s[:cb], cb, 0),
        _pad_chunk(ps_d[:cb], cb, 0),
        _pad_chunk(lo_d[:cb], cb, 0), _pad_chunk(hi_d[:cb], cb, 0),
        need_tab, jnp.int32(0),
    )
    statics = dict(sim=sim, tau=float(tau), cap=cap, lp=lp, scale=scale,
                   self_join=self_join, cutoff=int(cutoff), impl=impl)
    return args, statics


def _dense_chunk_fallback(tokens_r, lengths_r, words_r, tokens_c, lengths_c,
                          words_c, lo_c, hi_c, s0, *, sim, tau, cutoff, impl,
                          self_join):
    """Dense escalation for a probe chunk whose expansion overflowed a
    forced capacity: grid verdict over R × chunk, host compaction, batched
    exact verification (the blocked driver's classic route).

    Returns ``(n_window_cells, n_bitmap, verified sorted-space pairs)``.
    """
    cand = np.asarray(kops.candidate_matrix(
        words_r, words_c, lengths_r, lengths_c, sim=sim, tau=float(tau),
        self_join=False, cutoff=int(cutoff), impl=impl))
    np_lr = np.asarray(lengths_r)
    np_ls = np.asarray(lengths_c)
    win = ((np_lr[:, None] >= np.asarray(lo_c)[None, :])
           & (np_lr[:, None] <= np.asarray(hi_c)[None, :])
           & (np_lr[:, None] > 0) & (np_ls[None, :] > 0))
    if self_join:
        win &= (np.arange(len(np_lr))[:, None]
                < (s0 + np.arange(len(np_ls)))[None, :])
    cand = cand & win
    n_win = int(win.sum())
    ii, jj = np.nonzero(cand)
    if len(ii) == 0:
        return n_win, 0, np.zeros((0, 2), dtype=np.int64)
    ok = np.asarray(verify.verify_pairs_rs(
        tokens_r, lengths_r, tokens_c, lengths_c,
        jnp.asarray(ii), jnp.asarray(jj), sim, float(tau)))
    pairs = np.stack([ii[ok], jj[ok] + s0], axis=1).astype(np.int64)
    return n_win, len(ii), pairs


def _pad_chunk(a, rows: int, fill):
    pad = rows - a.shape[0]
    if pad == 0:
        return a
    widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=fill)


def probe_prefix_lengths(prep_s, sim: str, tau: float):
    """1-prefix schema lengths per probe row -> ``(ps_np int32[N], lp)``.

    Probe prefixes use the 1-prefix schema regardless of the index's ℓ (an
    ℓ-prefix index is a superset of the 1-prefix one, so matches are only
    ever added, never lost).  Shared by the single-device and sharded
    drivers so both expand the identical lookup set.
    """
    ns = prep_s.num_sets
    ps_np = np.zeros(ns, dtype=np.int32)
    nz = prep_s.lengths > 0
    if nz.any():
        ps_np[nz] = bounds.prefix_length(
            sim, tau, prep_s.lengths[nz].astype(np.int64)).astype(np.int32)
    return ps_np, int(ps_np.max(initial=0))


def finish_pairs(prep_r, prep_s, self_join: bool, pairs_list) -> np.ndarray:
    """Concatenate sorted-space chunk pair buffers, remap through the
    prepared orders to *original* indices, canonicalize (i < j for a
    self-join) and lexsort — every index-driven driver's epilogue."""
    if pairs_list:
        pairs = np.concatenate(pairs_list, axis=0)
        gi = prep_r.order[pairs[:, 0]]
        gj = prep_s.order[pairs[:, 1]]
        if self_join:
            pairs = np.stack([np.minimum(gi, gj), np.maximum(gi, gj)],
                             axis=1)
        else:
            pairs = np.stack([gi, gj], axis=1)
        pairs = pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]
        return pairs.astype(np.int64)
    return np.zeros((0, 2), dtype=np.int64)


def indexed_join_prepared(
    prep_r: PreparedCollection,
    prep_s: PreparedCollection | None = None,
    *,
    sim: str = JACCARD,
    tau: float = 0.8,
    b: int = 128,
    method: str = BITMAP_COMBINED,
    mix: bool = False,
    ell: int = 1,
    probe_block: int = 4096,
    impl: str = "auto",
    use_cutoff: bool = True,
    capacity: int | None = None,
    return_stats: bool = False,
):
    """Index-driven exact join over prepared inputs.

    The ℓ-prefix CSR postings index is built over R (cached on ``prep_r``
    per ``(sim, tau, ell)``); S streams through in ``probe_block``-sized
    chunks.  Self-join ONLY when ``prep_s`` is omitted (same contract as
    the other prepared drivers: explicitly passing the same object as both
    operands is a full R×S cross product including the diagonal).

    ``capacity=None`` (default) sizes each chunk's buffer from the count
    prepass, so nothing ever overflows; an explicit capacity bounds device
    memory and escalates overflowing chunks to a dense fallback
    (``JoinStats.overflow_blocks``), preserving exactness.

    Returns lexicographically sorted ``int64[K, 2]`` pairs in *original*
    indices — ``(i, j)`` with ``i < j`` for a self-join, ``(r_index,
    s_index)`` otherwise — exactly :func:`repro.core.join.naive_join`'s
    pair set (property-tested).  With ``return_stats=True`` also returns
    the candidate-funnel :class:`~repro.core.join.JoinStats`.
    """
    self_join = prep_s is None
    if self_join:
        prep_s = prep_r
    chosen = bm.choose_method(tau, b) if method == BITMAP_COMBINED else method
    cutoff = (expected.cutoff_point(chosen, b, float(tau)) if use_cutoff
              else 1 << 30)
    nr, ns = prep_r.num_sets, prep_s.num_sets
    stats = JoinStats()

    def _finish(pairs_list):
        pairs = finish_pairs(prep_r, prep_s, self_join, pairs_list)
        return (pairs, stats) if return_stats else pairs

    post = prep_r.postings(sim, tau, ell)
    ps_np, lp = probe_prefix_lengths(prep_s, sim, tau)
    if nr == 0 or ns == 0 or post.num_postings == 0 or lp == 0:
        return _finish([])

    tokens_r, lengths_r = prep_r.device_arrays()
    words_r = prep_r.bitmap_words(b, chosen, mix=mix)
    if self_join:
        tokens_s, lengths_s, words_s = tokens_r, lengths_r, words_r
    else:
        tokens_s, lengths_s = prep_s.device_arrays()
        words_s = prep_s.bitmap_words(b, chosen, mix=mix)
    # Admissible |r| window per probe row (cached per (sim, tau) on S).
    lo_np, hi_np, lo_d, hi_d = prep_s.length_window_int(sim, tau)
    ps_d = jnp.asarray(ps_np)
    csr = post.device_arrays()
    scale = post.max_len + 1
    need_tab = verify.min_overlap_table_dev(
        sim, float(tau), prep_r.max_len, prep_s.max_len)

    cb = int(probe_block)
    pairs_out: list[np.ndarray] = []
    for c0 in range(0, ns, cb):
        c1 = min(c0 + cb, ns)
        stats.blocks_total += 1
        n_exp = _expansion_count_host(
            post, prep_s.tokens[c0:c1], ps_np[c0:c1],
            lo_np[c0:c1], hi_np[c0:c1], lp, scale)
        stats.postings_expanded += n_exp
        if n_exp == 0:
            stats.blocks_skipped += 1
            continue
        if capacity is None:
            cap = min(_bucket_capacity(n_exp), nr * (c1 - c0) * lp)
        else:
            cap = int(capacity)
        if n_exp > cap or n_exp > _MAX_AUTO_CAPACITY:
            # The entry stream would truncate (forced capacity) or the
            # auto-sized buffer would be unreasonably large (pathological
            # hot-token chunk) — escalate the whole chunk to the dense
            # grid fallback.
            stats.overflow_blocks += 1
            n_win, n_bm, vpairs = _dense_chunk_fallback(
                tokens_r, lengths_r, words_r,
                tokens_s[c0:c1], lengths_s[c0:c1], words_s[c0:c1],
                np.asarray(lo_d[c0:c1]), np.asarray(hi_d[c0:c1]), c0,
                sim=sim, tau=tau, cutoff=cutoff, impl=impl,
                self_join=self_join)
            stats.total_pairs += n_win
            stats.candidates_generated += n_win
            stats.candidates += n_bm
            stats.verified_true += len(vpairs)
            if len(vpairs):
                pairs_out.append(vpairs)
            continue
        pairs_d, _, n_gen, n_bm, n_ok = _indexed_chunk_step(
            tokens_r, lengths_r, words_r, *csr,
            _pad_chunk(tokens_s[c0:c1], cb, PAD_TOKEN),
            _pad_chunk(lengths_s[c0:c1], cb, 0),
            _pad_chunk(words_s[c0:c1], cb, 0),
            _pad_chunk(ps_d[c0:c1], cb, 0),
            _pad_chunk(lo_d[c0:c1], cb, 0), _pad_chunk(hi_d[c0:c1], cb, 0),
            need_tab, jnp.int32(c0),
            sim=sim, tau=float(tau), cap=cap, lp=lp, scale=scale,
            self_join=self_join, cutoff=int(cutoff), impl=impl)
        stats.total_pairs += int(n_gen)
        stats.candidates_generated += int(n_gen)
        stats.candidates += int(n_bm)
        k = int(n_ok)
        stats.verified_true += k
        if k:
            pairs_out.append(np.asarray(pairs_d)[:k].astype(np.int64))

    return _finish(pairs_out)


def indexed_bitmap_join(
    col_r: Collection | PreparedCollection,
    col_s: Collection | PreparedCollection | str | None = None,
    sim: str = JACCARD,
    tau: float = 0.8,
    **kwargs,
):
    """Collection-level wrapper of :func:`indexed_join_prepared` (the
    ``blocked_bitmap_join`` calling convention: ``(col, sim, tau)`` for a
    self-join, ``(col_r, col_s, sim, tau)`` for R×S; plain collections are
    prepared on the spot, prepared ones reuse their caches)."""
    col_s, sim, tau = split_join_args(col_s, sim, tau)
    return indexed_join_prepared(
        as_prepared(col_r), None if col_s is None else as_prepared(col_s),
        sim=sim, tau=tau, **kwargs)
