"""Device-resident inverted prefix-index subsystem.

The CPU algorithms' prefix-filter inverted indexes, compiled into flat CSR
device arrays and driven by Pallas candidate-generation kernels — the first
driver family whose work scales with *candidate count* instead of |R|·|S|.

Public surface:

* :mod:`repro.index.postings` — :class:`PostingsIndex` (CSR ℓ-prefix
  postings, dense frequency-ordered token ids) + :func:`build_postings`;
  cached on :class:`~repro.core.engine.PreparedCollection` per
  ``(sim, tau, ell)``.
* :mod:`repro.index.candidates` — :func:`indexed_join_prepared` /
  :func:`indexed_bitmap_join`, the ``"indexed"`` join driver (registered in
  :mod:`repro.core.plan` and executed by
  :class:`~repro.core.engine.JoinEngine`).
"""

from repro.index.candidates import indexed_bitmap_join, indexed_join_prepared
from repro.index.postings import PostingsIndex, build_postings
