"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2 with a
dense (d_ff=4864) residual MLP in parallel.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    d_ff=4864,
    vocab_size=32000,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    num_experts=128,
    experts_per_token=2,
    moe_d_ff=4864,
    dense_residual=True,
).validate()
