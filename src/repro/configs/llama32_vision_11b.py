"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L total = 32 self-attn + 8 gated cross-attn layers (one after every 4 self
layers), d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.  The vision
tower is a stub per the assignment: ``input_specs`` provides precomputed
patch embeddings (1600 tokens, d_model).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    d_ff=14336,
    vocab_size=128256,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    cross_attn_every=4,
    num_image_tokens=1600,
    rope_theta=5e5,
).validate()
