"""mamba2-2.7b [ssm] — SSD (state-space duality) [arXiv:2405.21060; unverified].

64L d_model=2560 (attn-free) vocab=50280, ssm_state=128, head_dim=64,
expand=2 (d_inner=5120, 80 heads).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    tie_embeddings=True,
).validate()
