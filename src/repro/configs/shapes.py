"""Assigned input shapes and ShapeDtypeStruct builders.

Four shapes per architecture (assignment):
  train_4k     seq=4096,   global_batch=256  -> train_step
  prefill_32k  seq=32768,  global_batch=32   -> prefill_step
  decode_32k   seq=32768,  global_batch=128  -> serve_step (1 new token)
  long_500k    seq=524288, global_batch=1    -> serve_step (SSM/hybrid only)

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStructs for
every model input (tokens/labels or stub modality embeddings) — no device
allocation, per the dry-run contract.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    """long_500k only for sub-quadratic archs (skip noted in DESIGN.md §5)."""
    if shape == "long_500k":
        return cfg.subquadratic
    return True


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs as ShapeDtypeStructs (no allocation)."""
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    i32 = jnp.int32
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    seq = 1 if sp.kind == "decode" else s
    if cfg.frame_inputs:
        specs["frame_embeds"] = jax.ShapeDtypeStruct((b, seq, cfg.d_model), jnp.bfloat16)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((b, seq), i32)
    if sp.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, seq), i32)
    if cfg.family == "vlm" and sp.kind != "decode":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    return specs


def demo_batch(cfg: ModelConfig, batch: int, seq: int, rng=None) -> Dict[str, jnp.ndarray]:
    """Small concrete batch for smoke tests / examples."""
    import numpy as np

    rng = rng or np.random.default_rng(0)
    out: Dict[str, jnp.ndarray] = {}
    if cfg.frame_inputs:
        out["frame_embeds"] = jnp.asarray(
            rng.normal(size=(batch, seq, cfg.d_model)).astype("float32"), jnp.bfloat16)
    else:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(batch, seq)), jnp.int32)
    out["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(batch, seq)), jnp.int32)
    if cfg.family == "vlm":
        out["image_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.num_image_tokens, cfg.d_model)).astype("float32"),
            jnp.bfloat16)
    return out
