"""Architecture registry: the 10 assigned configs (+ reduced smoke variants).

``get(name)`` returns the full published config; ``get_reduced(name)`` a tiny
same-family config for CPU smoke tests.  ``ARCHS`` lists the selectable
``--arch`` ids.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, reduced

_MODULES = {
    "smollm-135m": "repro.configs.smollm_135m",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "minitron-8b": "repro.configs.minitron_8b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    "arctic-480b": "repro.configs.arctic_480b",
    "mamba2-2.7b": "repro.configs.mamba2_27b",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "musicgen-medium": "repro.configs.musicgen_medium",
}

ARCHS: List[str] = list(_MODULES)


def get(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_reduced(name: str, **overrides) -> ModelConfig:
    return reduced(get(name), **overrides)


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get(n) for n in ARCHS}
