"""zamba2-7b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242; unverified].

81L d_model=3584 32H (GQA kv=32 => MHA in the shared block) d_ff=14336
vocab=32000, ssm_state=64. The single weight-shared attention+MLP block is
applied every ``attn_every``=6 Mamba2 layers (13 applications + 3 tail
Mamba layers).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab_size=32000,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    ssm_state=64,
    ssm_head_dim=64,
    attn_every=6,
    tie_embeddings=True,
).validate()
