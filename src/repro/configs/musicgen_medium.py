"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].

48L d_model=1536 24H (kv=24 => MHA) d_ff=6144 vocab=2048.  The EnCodec
frontend (codebook interleaving / delay pattern) is a stub per the
assignment: ``input_specs`` provides precomputed frame embeddings; logits
target the 2048-entry codec vocabulary.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    d_ff=6144,
    vocab_size=2048,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    frame_inputs=True,
).validate()
