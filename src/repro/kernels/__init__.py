"""Pallas TPU kernels for the Bitmap Filter hot spots.

* :mod:`repro.kernels.bitmap_filter` — tiled SWAR xor+popcount Hamming /
  fused candidate kernels (pl.pallas_call + BlockSpec VMEM tiling).
* :mod:`repro.kernels.bitplane` — MXU int8 bit-plane reformulation.
* :mod:`repro.kernels.compaction` — tile-count prepass for device-resident
  candidate compaction (sizes the fixed-capacity buffers from real counts).
* :mod:`repro.kernels.postings` — index-driven candidate generation: the
  per-posting entry filter and the pairwise bitmap-verdict kernel consumed
  by the ``"indexed"`` driver (:mod:`repro.index`).
* :mod:`repro.kernels.ops` — jit'd public wrappers with impl dispatch.
* :mod:`repro.kernels.ref` — pure-jnp oracles for validation.
"""
