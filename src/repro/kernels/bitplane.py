"""MXU bit-plane Hamming kernel — the beyond-paper TPU reformulation.

On GPUs the fast path for the bitmap filter is ``XOR`` + ``__popc``.  On TPUs
the fast path is the 128x128 systolic array, so we re-express Hamming distance
as a matmul:

    popcount(x XOR y) = popcount(x) + popcount(y) - 2 * <bits(x), bits(y)>

After a one-time ``O(N*b)`` unpack of each bitmap into a {0,1} ``int8`` plane,
the all-pairs inner-product term becomes an ``int8 x int8 -> int32``
``dot_general`` that runs on the MXU.  Arithmetic intensity per output tile is
``2*b`` MACs vs ``~6*b/32`` VPU bit-ops for the SWAR kernel, but the MXU's
throughput advantage (~8x the VPU's int path at b >= 512) makes this the
preferred kernel for large bitmaps; `ops.hamming_matrix(impl='auto')`
dispatches on ``b``.

Per-row popcounts are precomputed (cheap, O(N*W)) and streamed in as
``(tile,)`` vectors.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 256


def _bitplane_kernel(pr_ref, ps_ref, pcr_ref, pcs_ref, out_ref):
    # pr: (TR, b) int8 bit planes; pcr: (TR,) int32 row popcounts.
    dot = jax.lax.dot_general(
        pr_ref[...],
        ps_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )  # (TR, TS) int32 — MXU
    out_ref[...] = pcr_ref[...][:, None] + pcs_ref[...][None, :] - 2 * dot


def bitplane_hamming_pallas(
    planes_r: jnp.ndarray,
    planes_s: jnp.ndarray,
    pc_r: jnp.ndarray,
    pc_s: jnp.ndarray,
    *,
    tile_r: int = DEFAULT_TILE,
    tile_s: int = DEFAULT_TILE,
    interpret: bool = False,
) -> jnp.ndarray:
    """int8[NR, b] x int8[NS, b] (+popcounts) -> int32[NR, NS] Hamming."""
    nr, b = planes_r.shape
    ns, _ = planes_s.shape
    grid = (nr // tile_r, ns // tile_s)
    return pl.pallas_call(
        _bitplane_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, b), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_s, b), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_r,), lambda i, j: (i,)),
            pl.BlockSpec((tile_s,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile_r, tile_s), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nr, ns), jnp.int32),
        interpret=interpret,
    )(planes_r, planes_s, pc_r, pc_s)
