"""Jit'd public wrappers around the Pallas kernels.

Handles tile padding, implementation dispatch, and the CPU story:

* on TPU the compiled Pallas kernels run natively;
* on CPU ``interpret=True`` executes the kernel bodies in Python — correct but
  slow, used by the test suite;
* ``impl='ref'`` (the pure-jnp oracle) is the default *performance* path on
  CPU so that benchmarks and the data pipeline stay fast in this container.

``impl='auto'`` resolves to: Pallas-SWAR for b < 512, Pallas-MXU-bitplane for
b >= 512 on TPU; ref on CPU.  The pairwise (1-D candidate stream) ops have
their own resolution (:func:`_resolve_pairwise_impl`): ``auto`` picks the
candidate-major tiled SWAR kernel for b < 512 and the batched bit-plane MXU
kernel for b >= 512 on TPU, so large-b candidate verdicts run on the
systolic array just like the dense grid path; ``entry_filter`` (pure
integer filtering, no bitmap words) maps the mxu impls to their elementwise
equivalents.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds
from repro.core.bitmap import popcount_rows, unpack_bits
from repro.kernels import bitplane, bitmap_filter, compaction, postings, ref

_TILE = bitmap_filter.DEFAULT_TILE
_TILE_1D = postings.DEFAULT_TILE_1D


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(a: jnp.ndarray, multiple: int, fill=0) -> jnp.ndarray:
    n = a.shape[0]
    rem = (-n) % multiple
    if rem == 0:
        return a
    pad_widths = [(0, rem)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad_widths, constant_values=fill)


def resolve_impl(impl: str, b: int) -> str:
    if impl != "auto":
        return impl
    if not _on_tpu():
        return "ref"
    return "mxu" if b >= 512 else "swar"


@functools.partial(jax.jit, static_argnames=("impl", "interpret", "tile"))
def hamming_matrix(
    words_r: jnp.ndarray,
    words_s: jnp.ndarray,
    impl: str = "auto",
    interpret: bool | None = None,
    tile: int = _TILE,
) -> jnp.ndarray:
    """All-pairs Hamming distance between packed bitmaps -> int32[NR, NS]."""
    nr, w = words_r.shape
    ns, _ = words_s.shape
    b = 32 * w
    impl = resolve_impl(impl, b)
    if interpret is None:
        interpret = not _on_tpu()
    if impl == "ref":
        return ref.hamming_matrix_ref(words_r, words_s)
    if impl == "ref_mxu":
        return ref.bitplane_hamming_ref(
            unpack_bits(words_r).astype(jnp.int8),
            unpack_bits(words_s).astype(jnp.int8),
            popcount_rows(words_r), popcount_rows(words_s))
    pr = _pad_rows(words_r, tile)
    ps = _pad_rows(words_s, tile)
    if impl == "swar":
        out = bitmap_filter.hamming_matrix_pallas(pr, ps, tile_r=tile, tile_s=tile,
                                                  interpret=interpret)
    elif impl == "mxu":
        planes_r = unpack_bits(pr).astype(jnp.int8)
        planes_s = unpack_bits(ps).astype(jnp.int8)
        out = bitplane.bitplane_hamming_pallas(
            planes_r, planes_s, popcount_rows(pr), popcount_rows(ps),
            tile_r=tile, tile_s=tile, interpret=interpret)
    else:
        raise ValueError(f"unknown impl {impl!r}")
    return out[:nr, :ns]


@functools.partial(
    jax.jit,
    static_argnames=("sim", "tau", "self_join", "cutoff", "impl", "interpret", "tile"),
)
def candidate_matrix(
    words_r: jnp.ndarray,
    words_s: jnp.ndarray,
    len_r: jnp.ndarray,
    len_s: jnp.ndarray,
    sim: str,
    tau: float,
    self_join: bool,
    cutoff: int = 1 << 30,
    impl: str = "auto",
    interpret: bool | None = None,
    tile: int = _TILE,
) -> jnp.ndarray:
    """Fused bitmap-filter verdicts -> bool[NR, NS] candidate mask."""
    nr, w = words_r.shape
    ns, _ = words_s.shape
    b = 32 * w
    impl = resolve_impl(impl, b)
    if interpret is None:
        interpret = not _on_tpu()
    if impl == "ref":
        return ref.candidate_matrix_ref(
            words_r, words_s, len_r, len_s, sim=sim, tau=tau,
            self_join=self_join, cutoff=cutoff)
    if impl == "ref_mxu":
        ham = hamming_matrix(words_r, words_s, impl="ref_mxu")
        lr = len_r.astype(jnp.int32)[:, None]
        ls = len_s.astype(jnp.int32)[None, :]
        ub = jnp.minimum((lr + ls - ham) // 2, jnp.minimum(lr, ls))
        need = bounds.required_overlap_safe(sim, tau, lr, ls)
        cand = (ub.astype(jnp.float32) >= need) | (lr > cutoff) | (ls > cutoff)
        cand &= (lr > 0) & (ls > 0)
        if self_join:
            cand &= jnp.arange(words_r.shape[0])[:, None] < jnp.arange(words_s.shape[0])[None, :]
        return cand
    if impl == "mxu":
        # MXU path computes Hamming on the systolic array, then applies the
        # (cheap, elementwise) verdict outside the kernel.
        ham = hamming_matrix(words_r, words_s, impl="mxu", interpret=interpret, tile=tile)
        lr = len_r.astype(jnp.int32)[:, None]
        ls = len_s.astype(jnp.int32)[None, :]
        ub = jnp.minimum((lr + ls - ham) // 2, jnp.minimum(lr, ls))
        need = bounds.required_overlap_safe(sim, tau, lr, ls)
        cand = (ub.astype(jnp.float32) >= need) | (lr > cutoff) | (ls > cutoff)
        cand &= (lr > 0) & (ls > 0)
        if self_join:
            cand &= jnp.arange(nr)[:, None] < jnp.arange(ns)[None, :]
        return cand
    pr = _pad_rows(words_r, tile)
    ps = _pad_rows(words_s, tile)
    plr = _pad_rows(len_r.astype(jnp.int32), tile)
    pls = _pad_rows(len_s.astype(jnp.int32), tile)
    out = bitmap_filter.candidate_matrix_pallas(
        pr, ps, plr, pls, sim=sim, tau=tau, self_join=self_join,
        cutoff=cutoff, tile_r=tile, tile_s=tile, interpret=interpret)
    return out[:nr, :ns]


@functools.partial(
    jax.jit,
    static_argnames=("sim", "tau", "self_join", "cutoff", "window", "impl",
                     "interpret", "tile"),
)
def count_candidates(
    words_r: jnp.ndarray,
    words_s: jnp.ndarray,
    len_r: jnp.ndarray,
    len_s: jnp.ndarray,
    lo_s: jnp.ndarray,
    hi_s: jnp.ndarray,
    sim: str,
    tau: float,
    self_join: bool = False,
    cutoff: int = 1 << 30,
    window: bool = True,
    impl: str = "auto",
    interpret: bool | None = None,
    tile: int = _TILE,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Tile-count prepass -> (window counts, candidate counts), int32[GR, GS].

    Counts exactly what :func:`candidate_matrix` intersected with the integer
    length window (``lo_s``/``hi_s`` per R row, from
    ``bounds.length_window_int``) would mark true — but without materialising
    the dense mask.  The resident join sizes its compaction capacity from
    these counts.  Non-Pallas impls (``ref``/``ref_mxu``/``mxu``) share the
    pure-jnp oracle; the dense intermediate then lives only on device inside
    this jit.
    """
    nr, w = words_r.shape
    ns, _ = words_s.shape
    b = 32 * w
    impl = resolve_impl(impl, b)
    if interpret is None:
        interpret = not _on_tpu()
    if impl in ("ref", "ref_mxu", "mxu"):
        return ref.count_candidates_ref(
            words_r, words_s, len_r, len_s, lo_s, hi_s, sim=sim, tau=tau,
            self_join=self_join, cutoff=cutoff, window=window,
            tile_r=tile, tile_s=tile)
    if impl != "swar":
        raise ValueError(f"unknown impl {impl!r}")
    pr = _pad_rows(words_r, tile)
    ps = _pad_rows(words_s, tile)
    plr = _pad_rows(len_r.astype(jnp.int32), tile)
    pls = _pad_rows(len_s.astype(jnp.int32), tile)
    plo = _pad_rows(lo_s.astype(jnp.int32), tile)
    phi = _pad_rows(hi_s.astype(jnp.int32), tile)
    return compaction.count_candidates_pallas(
        pr, ps, plr, pls, plo, phi, sim=sim, tau=tau, self_join=self_join,
        cutoff=cutoff, window=window, tile_r=tile, tile_s=tile,
        interpret=interpret)


def _resolve_pairwise_impl(impl: str, b: int) -> str:
    """Pairwise (1-D candidate stream) dispatch.

    ``auto`` resolves to ref on CPU; on TPU to the candidate-major tiled
    SWAR kernel for b < 512 and the batched bit-plane MXU kernel for
    b >= 512 (the 1-D analogue of the dense grid dispatch — the pairwise
    inner product is a batched ``dot_general``, so large-b verdicts run on
    the systolic array too).  Explicit impls pass through unchanged.
    """
    if impl != "auto":
        return impl
    if not _on_tpu():
        return "ref"
    return "mxu" if b >= 512 else "swar_tiled"


def _resolve_entry_impl(impl: str) -> str:
    """``entry_filter`` is pure integer filtering — there are no bitmap
    words, hence no bit-plane formulation; the mxu impls resolve to their
    elementwise equivalents (and ``swar_tiled`` to ``swar``: the kernel is
    already a single vectorized pass per tile)."""
    impl = resolve_impl(impl, 32)
    return {"mxu": "swar", "ref_mxu": "ref", "swar_tiled": "swar"}.get(impl, impl)


@functools.partial(
    jax.jit,
    static_argnames=("sim", "tau", "self_join", "impl", "interpret", "tile"),
)
def entry_filter(
    len_r: jnp.ndarray,
    pos_r: jnp.ndarray,
    len_s: jnp.ndarray,
    pos_s: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    idx_r: jnp.ndarray,
    idx_s: jnp.ndarray,
    valid: jnp.ndarray,
    sim: str,
    tau: float,
    self_join: bool = False,
    impl: str = "auto",
    interpret: bool | None = None,
    tile: int = _TILE_1D,
) -> jnp.ndarray:
    """Postings-entry admission mask -> bool[G] (index candidate generation).

    Applies the classic filters of :mod:`repro.core.filters` per expanded
    posting entry: the probe's integer length window on |r|, the positional
    upper bound at this matching prefix position, non-empty rows, and (for
    self-joins) the strict ``idx_r < idx_s`` triangle.  ``valid`` masks
    padding/overrun slots.
    """
    (g,) = len_r.shape
    impl = _resolve_entry_impl(impl)
    if interpret is None:
        interpret = not _on_tpu()
    args = (len_r, pos_r, len_s, pos_s, lo, hi, idx_r, idx_s)
    if impl == "ref":
        return ref.entry_filter_ref(*args, valid, sim=sim, tau=tau,
                                    self_join=self_join)
    if impl != "swar":
        raise ValueError(f"unknown impl {impl!r}")
    padded = [_pad_rows(a.astype(jnp.int32), tile) for a in args]
    pvalid = _pad_rows(valid, tile, fill=False)
    out = postings.entry_filter_pallas(
        *padded, pvalid, sim=sim, tau=tau, self_join=self_join, tile=tile,
        interpret=interpret)
    return out[:g]


@functools.partial(
    jax.jit,
    static_argnames=("sim", "tau", "cutoff", "impl", "interpret", "tile"),
)
def pair_verdict(
    words_r: jnp.ndarray,
    words_s: jnp.ndarray,
    len_r: jnp.ndarray,
    len_s: jnp.ndarray,
    sim: str,
    tau: float,
    cutoff: int = 1 << 30,
    impl: str = "auto",
    interpret: bool | None = None,
    tile: int = _TILE_1D,
) -> jnp.ndarray:
    """Pairwise fused bitmap-filter verdict -> bool[G].

    The same Eq. 2 + Table 1 + cutoff test as :func:`candidate_matrix`, but
    over *gathered* candidate rows (``words_r[g]`` vs ``words_s[g]``) instead
    of the dense cross product — the indexed driver's bitmap cost is
    proportional to G, not |R|·|S|.

    Impls (all bit-identical, conformance-gated against ``ref``):
    ``swar`` word-loop kernel, ``swar_tiled`` candidate-major streaming
    kernel, ``mxu`` batched bit-plane kernel, plus the ``ref``/``ref_mxu``
    pure-jnp oracles; ``auto`` picks per backend and b
    (:func:`_resolve_pairwise_impl`).
    """
    g, w = words_r.shape
    impl = _resolve_pairwise_impl(impl, 32 * w)
    if interpret is None:
        interpret = not _on_tpu()
    if impl == "ref":
        return ref.pair_verdict_ref(words_r, words_s, len_r, len_s,
                                    sim=sim, tau=tau, cutoff=cutoff)
    if impl == "ref_mxu":
        ham = ref.bitplane_pair_hamming_ref(
            unpack_bits(words_r).astype(jnp.int8),
            unpack_bits(words_s).astype(jnp.int8),
            popcount_rows(words_r), popcount_rows(words_s))
        return postings._verdict_from_hamming(
            ham, len_r.astype(jnp.int32), len_s.astype(jnp.int32),
            sim=sim, tau=tau, cutoff=cutoff)
    plr = _pad_rows(len_r.astype(jnp.int32), tile)
    pls = _pad_rows(len_s.astype(jnp.int32), tile)
    if impl == "mxu":
        pr = _pad_rows(words_r, tile)
        ps = _pad_rows(words_s, tile)
        out = postings.pair_verdict_bitplane_pallas(
            unpack_bits(pr).astype(jnp.int8), unpack_bits(ps).astype(jnp.int8),
            popcount_rows(pr), popcount_rows(ps), plr, pls,
            sim=sim, tau=tau, cutoff=cutoff, tile=tile, interpret=interpret)
        return out[:g]
    if impl not in ("swar", "swar_tiled"):
        raise ValueError(f"unknown impl {impl!r}")
    pr = _pad_rows(words_r, tile)
    ps = _pad_rows(words_s, tile)
    kernel = (postings.pair_verdict_tiled_pallas if impl == "swar_tiled"
              else postings.pair_verdict_pallas)
    out = kernel(
        pr, ps, plr, pls, sim=sim, tau=tau, cutoff=cutoff, tile=tile,
        interpret=interpret)
    return out[:g]
