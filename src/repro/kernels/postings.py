"""Pallas kernels for index-driven candidate generation.

The inverted prefix-index subsystem (:mod:`repro.index`) replaces the
O(|R|·|S|) verdict *grid* with a candidate *list*: probe prefix tokens are
looked up in a CSR postings index, matching entries are expanded into flat
``(probe, posting)`` streams, filtered, deduplicated and only then handed to
the bitmap filter + exact verification.  Two stages of that pipeline are
regular, elementwise, and hot enough to deserve kernels:

* :func:`entry_filter_pallas` — the per-posting admission test (length
  window on |r|, positional filter, non-empty rows, optional self-join
  triangle), the device form of the classic filters in
  :mod:`repro.core.filters`.  One bool per expanded posting entry.
* :func:`pair_verdict_pallas` — the bitmap-filter verdict evaluated
  *pairwise* over gathered candidate bitmaps (SWAR popcount over the packed
  words, Eq. 2 bound, Table 1 threshold, Algorithm 7 cutoff) — the same
  test as :func:`repro.kernels.bitmap_filter._tile_verdict` but over a flat
  candidate list instead of a dense (TR, TS) tile.  This is what makes the
  indexed driver's bitmap cost scale with *candidates generated* rather
  than grid cells.

The pairwise verdict has three kernel formulations, selected via
``ops.pair_verdict(impl=...)`` and all bit-identical to the ref oracle:

* ``swar`` (:func:`pair_verdict_pallas`) — the original word-loop kernel:
  ``fori_loop`` over the W packed words, one dynamic column slice per word.
* ``swar_tiled`` (:func:`pair_verdict_tiled_pallas`) — candidate-major
  tiling: each program XORs + popcounts its whole ``(tile, W)`` word block
  in one vectorized pass (the words stream through VMEM exactly once per
  tile, no per-word dynamic slicing) and reduces along the word axis.
  This is the roofline-driven rewrite: the ``swar`` loop issues W dependent
  dynamic slices per tile, the tiled form is a single streaming reduction.
* ``mxu`` (:func:`pair_verdict_bitplane_pallas`) — batched bit-plane
  form of :mod:`repro.kernels.bitplane` for the 1-D candidate stream:
  ``popcount(x XOR y) = pc(x) + pc(y) - 2·<bits(x), bits(y)>`` with the
  per-candidate inner product computed as a batched int8 ``dot_general``
  (batch dim = candidates, contraction over the b bit planes) that lowers
  onto the systolic array.

All kernels are 1-D over the entry/candidate stream (tile rows of
``DEFAULT_TILE_1D``), validated against the pure-jnp oracles in
:mod:`repro.kernels.ref` (``tests/test_postings_kernel.py``, interpret mode
on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bounds
from repro.kernels.bitmap_filter import _popcount32

DEFAULT_TILE_1D = 1024


def _entry_filter_body(lr, rpos, ls, spos, lo, hi, gi, gj, valid,
                       *, sim: str, tau: float, self_join: bool):
    """Shared admission test (kernel body == ref oracle, one copy)."""
    ok = valid & (lr > 0) & (ls > 0)
    # Length filter: |r| inside the probe's integer Table 2 window.
    ok &= (lr >= lo) & (lr <= hi)
    # Positional filter (Section 2.3.3) at this matching prefix position;
    # candidate generation ORs entries per pair, so this prunes a pair only
    # when *every* shared prefix token fails the bound — conservative.
    # Prune-side comparison -> epsilon-relaxed threshold (f32 may round up).
    ub = bounds.positional_upper_bound_int(lr, ls, rpos, spos)
    need = bounds.required_overlap_safe(sim, tau, lr, ls)
    ok &= ub.astype(jnp.float32) >= need
    if self_join:
        ok &= gi < gj
    return ok


def _make_entry_filter_kernel(sim: str, tau: float, self_join: bool):
    def kernel(lr_ref, rpos_ref, ls_ref, spos_ref, lo_ref, hi_ref,
               gi_ref, gj_ref, valid_ref, out_ref):
        out_ref[...] = _entry_filter_body(
            lr_ref[...].astype(jnp.int32), rpos_ref[...].astype(jnp.int32),
            ls_ref[...].astype(jnp.int32), spos_ref[...].astype(jnp.int32),
            lo_ref[...].astype(jnp.int32), hi_ref[...].astype(jnp.int32),
            gi_ref[...].astype(jnp.int32), gj_ref[...].astype(jnp.int32),
            valid_ref[...], sim=sim, tau=tau, self_join=self_join)

    return kernel


def entry_filter_pallas(
    len_r: jnp.ndarray,
    pos_r: jnp.ndarray,
    len_s: jnp.ndarray,
    pos_s: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    idx_r: jnp.ndarray,
    idx_s: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    sim: str,
    tau: float,
    self_join: bool,
    tile: int = DEFAULT_TILE_1D,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-entry admission mask -> bool[G] (G must be a tile multiple;
    ops.py pads with ``valid=False`` slots that never survive)."""
    (g,) = len_r.shape
    grid = (g // tile,)
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    kernel = _make_entry_filter_kernel(sim, float(tau), self_join)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * 9,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((g,), jnp.bool_),
        interpret=interpret,
    )(len_r, pos_r, len_s, pos_s, lo, hi, idx_r, idx_s, valid)


def _pairwise_hamming(r_words: jnp.ndarray, s_words: jnp.ndarray) -> jnp.ndarray:
    """(G, W) x (G, W) uint32 -> int32[G] pairwise Hamming distances."""
    w = r_words.shape[1]

    def body(k, acc):
        rw = jax.lax.dynamic_index_in_dim(r_words, k, 1, keepdims=False)
        sw = jax.lax.dynamic_index_in_dim(s_words, k, 1, keepdims=False)
        return acc + _popcount32(rw ^ sw).astype(jnp.int32)

    acc0 = jnp.zeros((r_words.shape[0],), dtype=jnp.int32)
    return jax.lax.fori_loop(0, w, body, acc0)


def _verdict_from_hamming(ham, lr, ls, *, sim: str, tau: float, cutoff: int):
    """Eq. 2 bound + Table 1 threshold + Alg. 7 cutoff, given the pairwise
    Hamming distances — shared by every pairwise verdict kernel so the three
    formulations differ only in how they compute ``ham``."""
    ub = (lr + ls - ham) // 2
    ub = jnp.minimum(ub, jnp.minimum(lr, ls))
    # Prune-side comparison -> epsilon-relaxed threshold (f32 may round up).
    need = bounds.required_overlap_safe(sim, tau, lr, ls)
    passed = ub.astype(jnp.float32) >= need
    # Cutoff (Alg. 7): past the precision cliff the bitmap test is void —
    # such pairs must be *kept* (conservative), not pruned.
    over_cut = (lr > cutoff) | (ls > cutoff)
    cand = passed | over_cut
    cand &= (lr > 0) & (ls > 0)
    return cand


def _pair_verdict_body(r_words, s_words, lr, ls, *, sim: str, tau: float,
                       cutoff: int):
    """Pairwise bitmap-filter verdict (kernel body == ref oracle)."""
    ham = _pairwise_hamming(r_words, s_words)
    return _verdict_from_hamming(ham, lr, ls, sim=sim, tau=tau, cutoff=cutoff)


def _make_pair_verdict_kernel(sim: str, tau: float, cutoff: int):
    def kernel(r_ref, s_ref, lr_ref, ls_ref, out_ref):
        out_ref[...] = _pair_verdict_body(
            r_ref[...], s_ref[...],
            lr_ref[...].astype(jnp.int32), ls_ref[...].astype(jnp.int32),
            sim=sim, tau=tau, cutoff=cutoff)

    return kernel


def pair_verdict_pallas(
    words_r: jnp.ndarray,
    words_s: jnp.ndarray,
    len_r: jnp.ndarray,
    len_s: jnp.ndarray,
    *,
    sim: str,
    tau: float,
    cutoff: int = 1 << 30,
    tile: int = DEFAULT_TILE_1D,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pairwise fused bitmap-filter verdict -> bool[G].

    ``words_r``/``words_s`` are *gathered* per-candidate packed bitmaps
    (uint32[G, W]); G must be a tile multiple (ops.py pads with length-0
    rows that are never candidates).
    """
    g, w = words_r.shape
    grid = (g // tile,)
    kernel = _make_pair_verdict_kernel(sim, float(tau), int(cutoff))
    vec_spec = pl.BlockSpec((tile,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, w), lambda i: (i, 0)),
            pl.BlockSpec((tile, w), lambda i: (i, 0)),
            vec_spec,
            vec_spec,
        ],
        out_specs=vec_spec,
        out_shape=jax.ShapeDtypeStruct((g,), jnp.bool_),
        interpret=interpret,
    )(words_r, words_s, len_r, len_s)


def _make_pair_verdict_tiled_kernel(sim: str, tau: float, cutoff: int):
    def kernel(r_ref, s_ref, lr_ref, ls_ref, out_ref):
        # Candidate-major: XOR + popcount the whole (tile, W) block at once —
        # the packed words stream through VMEM exactly once per tile — then
        # reduce along the word axis.  No per-word dynamic slicing.
        ham = jnp.sum(_popcount32(r_ref[...] ^ s_ref[...]).astype(jnp.int32),
                      axis=1)
        out_ref[...] = _verdict_from_hamming(
            ham, lr_ref[...].astype(jnp.int32), ls_ref[...].astype(jnp.int32),
            sim=sim, tau=tau, cutoff=cutoff)

    return kernel


def pair_verdict_tiled_pallas(
    words_r: jnp.ndarray,
    words_s: jnp.ndarray,
    len_r: jnp.ndarray,
    len_s: jnp.ndarray,
    *,
    sim: str,
    tau: float,
    cutoff: int = 1 << 30,
    tile: int = DEFAULT_TILE_1D,
    interpret: bool = False,
) -> jnp.ndarray:
    """Candidate-major tiled pairwise verdict -> bool[G] (same contract as
    :func:`pair_verdict_pallas`; one vectorized streaming pass per tile)."""
    g, w = words_r.shape
    grid = (g // tile,)
    kernel = _make_pair_verdict_tiled_kernel(sim, float(tau), int(cutoff))
    vec_spec = pl.BlockSpec((tile,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, w), lambda i: (i, 0)),
            pl.BlockSpec((tile, w), lambda i: (i, 0)),
            vec_spec,
            vec_spec,
        ],
        out_specs=vec_spec,
        out_shape=jax.ShapeDtypeStruct((g,), jnp.bool_),
        interpret=interpret,
    )(words_r, words_s, len_r, len_s)


def _make_pair_verdict_bitplane_kernel(sim: str, tau: float, cutoff: int):
    def kernel(pr_ref, ps_ref, pcr_ref, pcs_ref, lr_ref, ls_ref, out_ref):
        # Batched bit-plane inner product: batch dim = candidates,
        # contraction over the b planes -> int32[tile] on the MXU.
        dot = jax.lax.dot_general(
            pr_ref[...],
            ps_ref[...],
            dimension_numbers=(((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        )
        ham = pcr_ref[...] + pcs_ref[...] - 2 * dot
        out_ref[...] = _verdict_from_hamming(
            ham, lr_ref[...].astype(jnp.int32), ls_ref[...].astype(jnp.int32),
            sim=sim, tau=tau, cutoff=cutoff)

    return kernel


def pair_verdict_bitplane_pallas(
    planes_r: jnp.ndarray,
    planes_s: jnp.ndarray,
    pc_r: jnp.ndarray,
    pc_s: jnp.ndarray,
    len_r: jnp.ndarray,
    len_s: jnp.ndarray,
    *,
    sim: str,
    tau: float,
    cutoff: int = 1 << 30,
    tile: int = DEFAULT_TILE_1D,
    interpret: bool = False,
) -> jnp.ndarray:
    """Batched bit-plane (MXU) pairwise verdict -> bool[G].

    ``planes_r``/``planes_s`` are unpacked {0,1} int8 bit planes (int8[G, b],
    from :func:`repro.core.bitmap.unpack_bits`), ``pc_r``/``pc_s`` the
    precomputed per-row popcounts — the 1-D candidate-stream analogue of
    :func:`repro.kernels.bitplane.bitplane_hamming_pallas`.
    """
    g, b = planes_r.shape
    grid = (g // tile,)
    kernel = _make_pair_verdict_bitplane_kernel(sim, float(tau), int(cutoff))
    vec_spec = pl.BlockSpec((tile,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            vec_spec,
            vec_spec,
            vec_spec,
            vec_spec,
        ],
        out_specs=vec_spec,
        out_shape=jax.ShapeDtypeStruct((g,), jnp.bool_),
        interpret=interpret,
    )(planes_r, planes_s, pc_r, pc_s, len_r, len_s)
