"""Pallas tile-count prepass for device-resident candidate compaction.

The resident blocked join (``core/join.py``, ``compaction="device"``) keeps
filtering *and* compaction on device: candidates are packed into a
fixed-capacity buffer with ``jnp.nonzero(size=cap)`` inside one jit'd step,
so only compacted pairs and a few counters ever cross to the host.  The
capacity has to be a static (compile-time) size, and guessing it wrong means
either wasted VMEM/transfer or an overflow escalation — so this kernel
measures the *real* per-tile counts first.

Each grid program evaluates the same fused verdict as the candidate kernel
(:func:`repro.kernels.bitmap_filter._tile_verdict` — Eq. 2 bound, Table 1
threshold, cutoff, padding rows) plus the integer length-window and the
self-join triangle, then writes back two int32 scalars per tile: the number
of window-surviving pairs and the number of bitmap candidates.  O(NR*NS)
compute like the filter itself, but only ``O(grid)`` bytes of output —
roughly ``tile_r * tile_s / 4`` less HBM/host traffic than the dense bool
verdict tile the host-compaction path ships.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitmap_filter import DEFAULT_TILE, _tile_verdict


def _make_count_kernel(sim: str, tau: float, self_join: bool, cutoff: int,
                       window: bool, tile_r: int, tile_s: int):
    def kernel(r_ref, s_ref, lr_ref, ls_ref, lo_ref, hi_ref, win_ref, cand_ref):
        lr = lr_ref[...].astype(jnp.int32)  # (TR,)
        ls = ls_ref[...].astype(jnp.int32)  # (TS,)
        win = (lr[:, None] > 0) & (ls[None, :] > 0)
        if window:
            lo = lo_ref[...].astype(jnp.int32)
            hi = hi_ref[...].astype(jnp.int32)
            win &= (ls[None, :] >= lo[:, None]) & (ls[None, :] <= hi[:, None])
        if self_join:
            gi = pl.program_id(0) * tile_r + jax.lax.iota(jnp.int32, tile_r)
            gj = pl.program_id(1) * tile_s + jax.lax.iota(jnp.int32, tile_s)
            win &= gi[:, None] < gj[None, :]
        cand = _tile_verdict(r_ref[...], s_ref[...], lr, ls,
                             sim=sim, tau=tau, cutoff=cutoff) & win
        win_ref[0, 0] = jnp.sum(win.astype(jnp.int32))
        cand_ref[0, 0] = jnp.sum(cand.astype(jnp.int32))

    return kernel


def count_candidates_pallas(
    words_r: jnp.ndarray,
    words_s: jnp.ndarray,
    len_r: jnp.ndarray,
    len_s: jnp.ndarray,
    lo_s: jnp.ndarray,
    hi_s: jnp.ndarray,
    *,
    sim: str,
    tau: float,
    self_join: bool,
    cutoff: int = 1 << 30,
    window: bool = True,
    tile_r: int = DEFAULT_TILE,
    tile_s: int = DEFAULT_TILE,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tile (window-pair count, candidate count) -> two int32[GR, GS].

    NR/NS must be multiples of the tile sizes (ops.py pads; padded rows have
    length 0 and count in neither output).  ``lo_s``/``hi_s`` are int32[NR]
    admissible |s| windows per R row (``bounds.length_window_int``).
    """
    nr, w = words_r.shape
    ns, _ = words_s.shape
    grid = (nr // tile_r, ns // tile_s)
    kernel = _make_count_kernel(sim, float(tau), self_join, int(cutoff),
                                window, tile_r, tile_s)
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, w), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_s, w), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_r,), lambda i, j: (i,)),
            pl.BlockSpec((tile_s,), lambda i, j: (j,)),
            pl.BlockSpec((tile_r,), lambda i, j: (i,)),
            pl.BlockSpec((tile_r,), lambda i, j: (i,)),
        ],
        out_specs=(scalar_spec, scalar_spec),
        out_shape=(jax.ShapeDtypeStruct(grid, jnp.int32),
                   jax.ShapeDtypeStruct(grid, jnp.int32)),
        interpret=interpret,
    )(words_r, words_s, len_r, len_s, lo_s, hi_s)
