"""Fused flash-attention forward kernel (Pallas, TPU target).

This is the §Perf F lever for the dominant roofline term of every dense LM
cell: at the XLA level, blockwise attention round-trips the f32 score /
probability tiles through HBM (~S²·H·10 B per layer per pass — see
EXPERIMENTS.md §Roofline).  This kernel keeps the whole
QKᵀ → online-softmax → PV pipeline in VMEM: HBM traffic collapses to the
O(S·D) operand/output streams.

Layout: grid ``(B, H, nq)``; each program owns one (Cq, D) output block for
one (batch, head):

* q block   (Cq, D)   via BlockSpec (streamed per grid step);
* k/v rows  (Sk, D)   for the matching **KV head** (GQA via index_map
  ``h // group``) resident in VMEM — 8 MB at S=32k, D=128, bf16, within the
  ~16 MB budget; longer contexts tile kv with an extra grid dim;
* inner ``fori_loop`` over kv chunks with causal block skipping
  (lower-triangle schedule — upper blocks are never touched, which also
  halves FLOPs vs the masked-rectangle jnp path).

Forward-only by design: training keeps the custom-VJP jnp path (whose
backward is itself blockwise); serving/prefill — where the memory term binds
hardest — uses this kernel on TPU.  Validated against
``repro.models.layers.flash_attention`` in interpret mode
(tests/test_kernels.py::test_flash_kernel_*).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _make_kernel(sq: int, sk: int, q_chunk: int, kv_chunk: int, causal: bool,
                 scale: float):
    nk = sk // kv_chunk

    def kernel(q_ref, k_ref, v_ref, o_ref):
        qi = pl.program_id(2)
        q = q_ref[0, :, 0, :].astype(jnp.float32)              # (Cq, D)
        q_pos = qi * q_chunk + jax.lax.iota(jnp.int32, q_chunk)

        def body(kj, acc):
            o, m, l = acc
            # Indices must all be slices: a bare python int trips the
            # interpret-mode discharge rule in this JAX version.
            k_blk = pl.load(k_ref, (pl.dslice(0, 1),
                                    pl.dslice(kj * kv_chunk, kv_chunk),
                                    pl.dslice(0, 1),
                                    slice(None)))[0, :, 0, :].astype(jnp.float32)
            v_blk = pl.load(v_ref, (pl.dslice(0, 1),
                                    pl.dslice(kj * kv_chunk, kv_chunk),
                                    pl.dslice(0, 1),
                                    slice(None)))[0, :, 0, :]
            s = jax.lax.dot_general(
                q, k_blk, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale     # (Cq, Ck)
            if causal:
                k_pos = kj * kv_chunk + jax.lax.iota(jnp.int32, kv_chunk)
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[:, None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jax.lax.dot_general(
                p.astype(v_blk.dtype), v_blk, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            return (o * alpha[:, None] + pv, m_new, l_new)

        d = q_ref.shape[-1]  # head dim
        o0 = jnp.zeros((q_chunk, d), jnp.float32)
        m0 = jnp.full((q_chunk,), NEG_INF, jnp.float32)
        l0 = jnp.zeros((q_chunk,), jnp.float32)
        if causal:
            # lower-triangle schedule: kv blocks strictly above the diagonal
            # are never visited (static upper bound per q block).
            hi = (qi + 1) * q_chunk  # last kv index needed + 1
            n_valid = (hi + kv_chunk - 1) // kv_chunk
            o, m, l = jax.lax.fori_loop(0, n_valid, body, (o0, m0, l0))
        else:
            o, m, l = jax.lax.fori_loop(0, nk, body, (o0, m0, l0))
        o_ref[0, :, 0, :] = (o / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)

    return kernel


def flash_attention_fwd_pallas(
    q: jnp.ndarray,            # (B, Sq, H, D)
    k: jnp.ndarray,            # (B, Sk, KV, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_chunk: int = 256,
    kv_chunk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    assert h % kv == 0
    g = h // kv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    while sq % q_chunk:
        q_chunk //= 2
    while sk % kv_chunk:
        kv_chunk //= 2
    nq = sq // q_chunk
    kernel = _make_kernel(sq, sk, q_chunk, kv_chunk, causal, d ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq),
        in_specs=[
            pl.BlockSpec((1, q_chunk, 1, d), lambda bb, hh, i: (bb, i, hh, 0)),
            pl.BlockSpec((1, sk, 1, d), lambda bb, hh, i, g=g: (bb, 0, hh // g, 0)),
            pl.BlockSpec((1, sk, 1, d), lambda bb, hh, i, g=g: (bb, 0, hh // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_chunk, 1, d), lambda bb, hh, i: (bb, i, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out


def analytic_hbm_bytes(b, s, h, d, dtype_bytes=2) -> dict:
    """Roofline accounting for EXPERIMENTS §Perf F: fused vs unfused."""
    operands = 3 * b * s * h * d * dtype_bytes + b * s * h * d * dtype_bytes
    # unfused XLA path: s(f32) + p(bf16->dot copy) + pv(f32) tiles round-trip
    unfused_tiles = b * h * s * s * (4 + 2 + 4)
    return {"fused": operands, "unfused": operands + unfused_tiles,
            "ratio": (operands + unfused_tiles) / operands}
