"""Pure-jnp oracles for every kernel in this package.

These are the ground truth the Pallas kernels are validated against
(``tests/test_kernels.py`` sweeps shapes/dtypes and asserts exact equality —
all kernels here are integer/boolean, so there is no tolerance).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.bitmap import popcount32, unpack_bits
from repro.core.constants import COSINE, DICE, JACCARD, OVERLAP


def hamming_matrix_ref(words_r: jnp.ndarray, words_s: jnp.ndarray) -> jnp.ndarray:
    """uint32[NR, W] x uint32[NS, W] -> int32[NR, NS]."""
    x = words_r[:, None, :] ^ words_s[None, :, :]
    return jnp.sum(popcount32(x).astype(jnp.int32), axis=-1)


def bitplane_hamming_ref(planes_r: jnp.ndarray, planes_s: jnp.ndarray,
                         pc_r: jnp.ndarray, pc_s: jnp.ndarray) -> jnp.ndarray:
    dot = jnp.einsum("ib,jb->ij", planes_r.astype(jnp.int32), planes_s.astype(jnp.int32))
    return pc_r[:, None] + pc_s[None, :] - 2 * dot


def required_overlap_ref(sim: str, tau: float, lr: jnp.ndarray, ls: jnp.ndarray) -> jnp.ndarray:
    lr = lr.astype(jnp.float32)
    ls = ls.astype(jnp.float32)
    if sim == OVERLAP:
        return jnp.full(jnp.broadcast_shapes(lr.shape, ls.shape), float(tau), jnp.float32)
    if sim == JACCARD:
        return (tau / (1.0 + tau)) * (lr + ls)
    if sim == COSINE:
        return tau * jnp.sqrt(lr * ls)
    if sim == DICE:
        return (tau / 2.0) * (lr + ls)
    raise ValueError(sim)


def candidate_matrix_ref(
    words_r: jnp.ndarray,
    words_s: jnp.ndarray,
    len_r: jnp.ndarray,
    len_s: jnp.ndarray,
    *,
    sim: str,
    tau: float,
    self_join: bool,
    cutoff: int = 1 << 30,
) -> jnp.ndarray:
    ham = hamming_matrix_ref(words_r, words_s)
    lr = len_r.astype(jnp.int32)[:, None]
    ls = len_s.astype(jnp.int32)[None, :]
    ub = (lr + ls - ham) // 2
    ub = jnp.minimum(ub, jnp.minimum(lr, ls))
    need = required_overlap_ref(sim, tau, lr, ls)
    passed = ub.astype(jnp.float32) >= need
    over_cut = (lr > cutoff) | (ls > cutoff)
    cand = passed | over_cut
    cand &= (lr > 0) & (ls > 0)
    if self_join:
        nr = words_r.shape[0]
        ns = words_s.shape[0]
        gi = jnp.arange(nr)[:, None]
        gj = jnp.arange(ns)[None, :]
        cand &= gi < gj
    return cand
