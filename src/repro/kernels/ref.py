"""Pure-jnp oracles for every kernel in this package.

These are the ground truth the Pallas kernels are validated against
(``tests/test_kernels.py`` sweeps shapes/dtypes and asserts exact equality —
all kernels here are integer/boolean, so there is no tolerance).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import bounds
from repro.core.bitmap import popcount32, unpack_bits


def hamming_matrix_ref(words_r: jnp.ndarray, words_s: jnp.ndarray) -> jnp.ndarray:
    """uint32[NR, W] x uint32[NS, W] -> int32[NR, NS]."""
    x = words_r[:, None, :] ^ words_s[None, :, :]
    return jnp.sum(popcount32(x).astype(jnp.int32), axis=-1)


def bitplane_hamming_ref(planes_r: jnp.ndarray, planes_s: jnp.ndarray,
                         pc_r: jnp.ndarray, pc_s: jnp.ndarray) -> jnp.ndarray:
    dot = jnp.einsum("ib,jb->ij", planes_r.astype(jnp.int32), planes_s.astype(jnp.int32))
    return pc_r[:, None] + pc_s[None, :] - 2 * dot


def bitplane_pair_hamming_ref(planes_r: jnp.ndarray, planes_s: jnp.ndarray,
                              pc_r: jnp.ndarray, pc_s: jnp.ndarray) -> jnp.ndarray:
    """Pairwise (1-D stream) bit-plane Hamming: int8[G, b] x2 -> int32[G].

    Independent oracle of the batched-MXU pairwise kernel — the identity
    ``popcount(x XOR y) = pc(x) + pc(y) - 2·<bits(x), bits(y)>`` evaluated
    per candidate instead of all-pairs.
    """
    dot = jnp.einsum("gb,gb->g", planes_r.astype(jnp.int32),
                     planes_s.astype(jnp.int32))
    return pc_r + pc_s - 2 * dot


# The Table 1 equivalent-overlap threshold lives in core.bounds; kernels, the
# ring join and these oracles all share the same float32 helper.
required_overlap_ref = bounds.required_overlap


def candidate_matrix_ref(
    words_r: jnp.ndarray,
    words_s: jnp.ndarray,
    len_r: jnp.ndarray,
    len_s: jnp.ndarray,
    *,
    sim: str,
    tau: float,
    self_join: bool,
    cutoff: int = 1 << 30,
) -> jnp.ndarray:
    ham = hamming_matrix_ref(words_r, words_s)
    lr = len_r.astype(jnp.int32)[:, None]
    ls = len_s.astype(jnp.int32)[None, :]
    ub = (lr + ls - ham) // 2
    ub = jnp.minimum(ub, jnp.minimum(lr, ls))
    # Prune-side comparison -> epsilon-relaxed threshold (f32 may round up).
    need = bounds.required_overlap_safe(sim, tau, lr, ls)
    passed = ub.astype(jnp.float32) >= need
    over_cut = (lr > cutoff) | (ls > cutoff)
    cand = passed | over_cut
    cand &= (lr > 0) & (ls > 0)
    if self_join:
        nr = words_r.shape[0]
        ns = words_s.shape[0]
        gi = jnp.arange(nr)[:, None]
        gj = jnp.arange(ns)[None, :]
        cand &= gi < gj
    return cand


def entry_filter_ref(
    len_r: jnp.ndarray,
    pos_r: jnp.ndarray,
    len_s: jnp.ndarray,
    pos_s: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    idx_r: jnp.ndarray,
    idx_s: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    sim: str,
    tau: float,
    self_join: bool,
) -> jnp.ndarray:
    """Pure-jnp oracle of the postings entry-filter kernel.

    Independent formulation (masked where-chains instead of the kernel's
    boolean algebra) of the same admission test: non-empty rows, the
    probe's integer length window on |r|, the Section 2.3.3 positional
    bound at this matching prefix position, and (self-join) the strict
    upper triangle in sorted ids.
    """
    lr = len_r.astype(jnp.int32)
    ls = len_s.astype(jnp.int32)
    ub = 1 + jnp.where(lr - pos_r <= ls - pos_s, lr - pos_r, ls - pos_s) - 1
    need = bounds.required_overlap_safe(sim, tau, lr, ls)
    ok = jnp.where(valid & (lr > 0) & (ls > 0), True, False)
    ok = jnp.where((lr >= lo.astype(jnp.int32)) & (lr <= hi.astype(jnp.int32)),
                   ok, False)
    ok = jnp.where(ub.astype(jnp.float32) >= need, ok, False)
    if self_join:
        ok = jnp.where(idx_r < idx_s, ok, False)
    return ok


def pair_verdict_ref(
    words_r: jnp.ndarray,
    words_s: jnp.ndarray,
    len_r: jnp.ndarray,
    len_s: jnp.ndarray,
    *,
    sim: str,
    tau: float,
    cutoff: int = 1 << 30,
) -> jnp.ndarray:
    """Pure-jnp oracle of the pairwise bitmap-verdict kernel.

    Independent formulation (XOR + popcount over the full word axis, no
    fori_loop) so kernel bugs cannot hide behind a shared implementation;
    agrees elementwise with ``candidate_matrix_ref``'s diagonal.
    """
    ham = jnp.sum(popcount32(words_r ^ words_s).astype(jnp.int32), axis=-1)
    lr = len_r.astype(jnp.int32)
    ls = len_s.astype(jnp.int32)
    ub = jnp.minimum((lr + ls - ham) // 2, jnp.minimum(lr, ls))
    need = bounds.required_overlap_safe(sim, tau, lr, ls)
    cand = (ub.astype(jnp.float32) >= need) | (lr > cutoff) | (ls > cutoff)
    return cand & (lr > 0) & (ls > 0)


def count_candidates_ref(
    words_r: jnp.ndarray,
    words_s: jnp.ndarray,
    len_r: jnp.ndarray,
    len_s: jnp.ndarray,
    lo_s: jnp.ndarray,
    hi_s: jnp.ndarray,
    *,
    sim: str,
    tau: float,
    self_join: bool,
    cutoff: int = 1 << 30,
    window: bool = True,
    tile_r: int = 256,
    tile_s: int = 256,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tile (window-pair count, candidate count) -> two int32[GR, GS].

    ``lo_s``/``hi_s`` are the integer admissible |s| windows per R row
    (:func:`repro.core.bounds.length_window_int`).  Tiling matches the Pallas
    count kernel: row tile ``tile_r``, column tile ``tile_s``, last tiles
    padded with empty (length-0) rows that never count.
    """
    nr = words_r.shape[0]
    ns = words_s.shape[0]
    lr = len_r.astype(jnp.int32)[:, None]
    ls = len_s.astype(jnp.int32)[None, :]
    win = (lr > 0) & (ls > 0)
    if window:
        win &= (ls >= lo_s.astype(jnp.int32)[:, None]) & (ls <= hi_s.astype(jnp.int32)[:, None])
    if self_join:
        win &= jnp.arange(nr)[:, None] < jnp.arange(ns)[None, :]
    cand = candidate_matrix_ref(words_r, words_s, len_r, len_s, sim=sim,
                                tau=tau, self_join=self_join, cutoff=cutoff) & win

    def tile_sums(m):
        gr = -(-nr // tile_r)
        gs = -(-ns // tile_s)
        p = jnp.zeros((gr * tile_r, gs * tile_s), jnp.int32)
        p = p.at[:nr, :ns].set(m.astype(jnp.int32))
        return p.reshape(gr, tile_r, gs, tile_s).sum(axis=(1, 3))

    return tile_sums(win), tile_sums(cand)
