"""Pallas TPU kernels for the Bitmap Filter hot spot.

The paper's inner loop — ``popcount(b_r XOR b_s)`` for every candidate pair —
is re-tiled for the TPU memory hierarchy:

* grid ``(NR/TR, NS/TS)``; each program owns one ``(TR, TS)`` output tile;
* BlockSpecs stage ``(TR, W)`` and ``(TS, W)`` packed ``uint32`` bitmap blocks
  (plus the two length vectors) from HBM into VMEM;
* the Hamming accumulation loops over the ``W = b/32`` words with a SWAR
  popcount on the 8x128 VPU (TPUs have no scalar POPCNT — the bit-slice
  reduction is the vector-unit equivalent);
* the *fused* candidate kernel additionally evaluates the Eq. 2 overlap upper
  bound, the equivalent-overlap threshold (Table 1), and the self-join
  upper-triangle mask, emitting a compact ``bool`` tile. This fusion is the
  TPU analogue of the paper's GPU kernel (Algorithm 8): filter evaluation
  never leaves the core's registers/VMEM, and only a 1-bit verdict per pair
  is written back to HBM.

Default tiles: ``TR = TS = 256`` — the ``(256, 256)`` int32 accumulator is
256 KiB, both bitmap blocks at b=4096 are 128 KiB each, everything fits VMEM
(~16 MiB) with headroom; the 256-lane minor dim is a multiple of the 128-wide
vector lanes and MXU tiles.

Correctness of every kernel is asserted against ``repro.kernels.ref`` oracles
in ``tests/test_kernels.py`` (interpret mode on CPU; shape/dtype sweeps).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bounds

DEFAULT_TILE = 256


def _popcount32(v: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount on uint32 lanes (VPU-friendly, branch-free)."""
    v = v - ((v >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    v = (v + (v >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return (v * jnp.uint32(0x01010101)) >> jnp.uint32(24)


def _tile_hamming(r_words: jnp.ndarray, s_words: jnp.ndarray) -> jnp.ndarray:
    """(TR, W) x (TS, W) uint32 -> (TR, TS) int32 Hamming distances.

    Loops over words so the (TR, TS, W) cross-product is never materialised;
    the accumulator tile stays resident in registers/VMEM.
    """
    tr, w = r_words.shape
    ts = s_words.shape[0]

    def body(k, acc):
        rw = jax.lax.dynamic_index_in_dim(r_words, k, 1, keepdims=False)  # (TR,)
        sw = jax.lax.dynamic_index_in_dim(s_words, k, 1, keepdims=False)  # (TS,)
        x = rw[:, None] ^ sw[None, :]
        return acc + _popcount32(x).astype(jnp.int32)

    acc0 = jnp.zeros((tr, ts), dtype=jnp.int32)
    return jax.lax.fori_loop(0, w, body, acc0)


# ---------------------------------------------------------------------------
# Kernel 1: raw Hamming-distance tile kernel
# ---------------------------------------------------------------------------

def _hamming_kernel(r_ref, s_ref, out_ref):
    out_ref[...] = _tile_hamming(r_ref[...], s_ref[...])


def hamming_matrix_pallas(
    words_r: jnp.ndarray,
    words_s: jnp.ndarray,
    *,
    tile_r: int = DEFAULT_TILE,
    tile_s: int = DEFAULT_TILE,
    interpret: bool = False,
) -> jnp.ndarray:
    """All-pairs Hamming distance. uint32[NR, W] x uint32[NS, W] -> int32[NR, NS].

    NR/NS must be multiples of the tile sizes (ops.py pads).
    """
    nr, w = words_r.shape
    ns, _ = words_s.shape
    grid = (nr // tile_r, ns // tile_s)
    return pl.pallas_call(
        _hamming_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, w), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_s, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_r, tile_s), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nr, ns), jnp.int32),
        interpret=interpret,
    )(words_r, words_s)


# ---------------------------------------------------------------------------
# Kernel 2: fused candidate kernel (bound + threshold + triangle mask)
# ---------------------------------------------------------------------------

def _tile_verdict(r_words: jnp.ndarray, s_words: jnp.ndarray,
                  lr: jnp.ndarray, ls: jnp.ndarray,
                  *, sim: str, tau: float, cutoff: int) -> jnp.ndarray:
    """Fused bitmap-filter verdict for one tile -> bool[TR, TS].

    Shared by the candidate kernel below and the count prepass kernel in
    :mod:`repro.kernels.compaction` so both apply exactly the same test.
    """
    ham = _tile_hamming(r_words, s_words)
    lsum = lr[:, None] + ls[None, :]
    ub = (lsum - ham) // 2
    # Tighten: overlap can never exceed min(|r|, |s|).
    ub = jnp.minimum(ub, jnp.minimum(lr[:, None], ls[None, :]))
    # Prune against the epsilon-relaxed threshold: float32 rounding may sit
    # a few ulps above the f64 oracle value, and a prune is irreversible.
    need = bounds.required_overlap_safe(sim, tau, lr[:, None], ls[None, :])
    passed = ub.astype(jnp.float32) >= need
    # Cutoff (Alg. 7): past the precision cliff the bitmap test is void —
    # such pairs must be *kept* (conservative), not pruned.
    over_cut = (lr[:, None] > cutoff) | (ls[None, :] > cutoff)
    cand = passed | over_cut
    # Padding rows have length 0 -> never candidates.
    cand &= (lr[:, None] > 0) & (ls[None, :] > 0)
    return cand


def _make_candidate_kernel(sim: str, tau: float, self_join: bool, tile_r: int, tile_s: int,
                           cutoff: int):
    def kernel(r_ref, s_ref, lr_ref, ls_ref, out_ref):
        lr = lr_ref[...].astype(jnp.int32)  # (TR,)
        ls = ls_ref[...].astype(jnp.int32)  # (TS,)
        cand = _tile_verdict(r_ref[...], s_ref[...], lr, ls,
                             sim=sim, tau=tau, cutoff=cutoff)
        if self_join:
            gi = pl.program_id(0) * tile_r + jax.lax.iota(jnp.int32, tile_r)
            gj = pl.program_id(1) * tile_s + jax.lax.iota(jnp.int32, tile_s)
            cand &= gi[:, None] < gj[None, :]
        out_ref[...] = cand

    return kernel


def candidate_matrix_pallas(
    words_r: jnp.ndarray,
    words_s: jnp.ndarray,
    len_r: jnp.ndarray,
    len_s: jnp.ndarray,
    *,
    sim: str,
    tau: float,
    self_join: bool,
    cutoff: int = 1 << 30,
    tile_r: int = DEFAULT_TILE,
    tile_s: int = DEFAULT_TILE,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused bitmap-filter verdict tile: bool[NR, NS] candidate mask."""
    nr, w = words_r.shape
    ns, _ = words_s.shape
    grid = (nr // tile_r, ns // tile_s)
    kernel = _make_candidate_kernel(sim, float(tau), self_join, tile_r, tile_s, int(cutoff))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, w), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_s, w), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_r,), lambda i, j: (i,)),
            pl.BlockSpec((tile_s,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile_r, tile_s), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nr, ns), jnp.bool_),
        interpret=interpret,
    )(words_r, words_s, len_r, len_s)
