"""Train state + the pjit train step (with microbatch gradient accumulation).

The step is a pure function ``(state, batch) -> (state, metrics)`` suitable
for ``jax.jit(..., in_shardings=..., out_shardings=..., donate_argnums=0)``.
Sharding specs for the full state come from :func:`state_specs` (params from
``Model.param_specs``, optimizer state mirroring them — i.e. ZeRO-sharded).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.model import Model
from repro.train import optimizer as opt_lib
from repro.train.optimizer import OptimizerConfig


def init_state(model: Model, opt_cfg: OptimizerConfig, rng: jax.Array) -> Dict[str, Any]:
    params = model.init(rng)
    return {
        "step": jnp.zeros((), jnp.int32),
        "params": params,
        "opt": opt_lib.opt_init(opt_cfg, params),
    }


def state_shapes(model: Model, opt_cfg: OptimizerConfig) -> Dict[str, Any]:
    return jax.eval_shape(lambda: init_state(model, opt_cfg, jax.random.PRNGKey(0)))


def state_specs(model: Model, opt_cfg: OptimizerConfig, mesh,
                fsdp: Tuple[str, ...] = ("pod", "data"), tp: str = "model"):
    pspecs = model.param_specs(mesh, fsdp=fsdp, tp=tp)
    return {
        "step": P(),
        "params": pspecs,
        "opt": opt_lib.opt_state_specs(opt_cfg, pspecs),
    }


def batch_specs(model: Model, mesh, batch_axes: Tuple[str, ...] = ("pod", "data")):
    axes = tuple(a for a in batch_axes if a in mesh.shape)
    cfg = model.cfg
    specs: Dict[str, P] = {}
    if cfg.frame_inputs:
        specs["frame_embeds"] = P(axes, None, None)
    else:
        specs["tokens"] = P(axes, None)
    specs["labels"] = P(axes, None)
    if cfg.family == "vlm":
        specs["image_embeds"] = P(axes, None, None)
    return specs


def make_train_step(model: Model, opt_cfg: OptimizerConfig,
                    *, microbatches: int = 1, triangle: bool = False,
                    batch_axes: Tuple[str, ...] = ("pod", "data")):
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatches > 1`` accumulates gradients over sequential microbatch
    slices (lax.scan) — smaller live activation footprint, same math.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch, triangle=triangle)

    def grads_of(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        def mb_slice(b, i):
            return jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // microbatches), x.shape[0] // microbatches, 0),
                b)

        def body(carry, i):
            loss_acc, metrics_acc, g_acc = carry
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb_slice(batch, i))
            g_acc = jax.tree.map(jnp.add, g_acc, grads)
            metrics_acc = jax.tree.map(jnp.add, metrics_acc, metrics)
            return (loss_acc + loss, metrics_acc, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        metrics_shape = jax.eval_shape(loss_fn, params, mb_slice(batch, jnp.int32(0)))[1]
        m0 = jax.tree.map(lambda m: jnp.zeros(m.shape, m.dtype), metrics_shape)
        (loss_sum, metrics_sum, grads), _ = jax.lax.scan(
            body, (jnp.float32(0), m0, g0), jnp.arange(microbatches))
        inv = 1.0 / microbatches
        return (loss_sum * inv,
                jax.tree.map(lambda m: m * inv, metrics_sum),
                jax.tree.map(lambda g: g * inv, grads))

    def train_step(state, batch):
        params = state["params"]
        loss, metrics, grads = grads_of(params, batch)
        grads, gnorm = opt_lib.clip_by_global_norm(grads, opt_cfg.grad_clip)
        new_params, new_opt, lr = opt_lib.opt_update(
            opt_cfg, params, grads, state["opt"], state["step"])
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        new_state = {"step": state["step"] + 1, "params": new_params, "opt": new_opt}
        return new_state, metrics

    return train_step


def jit_train_step(model: Model, opt_cfg: OptimizerConfig, mesh,
                   *, microbatches: int = 1, triangle: bool = False,
                   fsdp: Tuple[str, ...] = ("pod", "data"), tp: str = "model",
                   donate: bool = True):
    """The fully-specified pjit'd step (used by launch/train.py and dryrun)."""
    from jax.sharding import NamedSharding

    sspecs = state_specs(model, opt_cfg, mesh, fsdp=fsdp, tp=tp)
    bspecs = batch_specs(model, mesh, batch_axes=fsdp)
    step = make_train_step(model, opt_cfg, microbatches=microbatches, triangle=triangle)
    metric_specs = None  # replicated metrics
    return jax.jit(
        step,
        in_shardings=(sspecs, bspecs),
        out_shardings=(sspecs, metric_specs),
        donate_argnums=(0,) if donate else (),
    ), sspecs, bspecs
