"""Optimizers: AdamW (default) and Adafactor (memory-lean option for the
largest MoE configs).  Implemented directly (no optax dependency in this
container) as pure pytree transforms whose state mirrors the parameter
sharding — optimizer state is therefore automatically ZeRO-sharded by the
same FSDP specs as the parameters.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # int8 stochastic-rounding compression of the cross-pod gradient
    # all-reduce (see repro.train.compress).
    compress_cross_pod: bool = False


def lr_schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * jnp.where(step < cfg.warmup_steps, warm, decay)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params), "nu": jax.tree.map(zeros, params)}


def adamw_update(cfg: OptimizerConfig, params, grads, state, step):
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / c1
        nhat = nu / c2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"mu": new_mu, "nu": new_nu}, lr


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment by default)
# ---------------------------------------------------------------------------

def adafactor_init(params):
    def factored(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(factored, params,
                              is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(cfg: OptimizerConfig, params, grads, state, step):
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    beta2 = 1.0 - t ** -0.8

    def upd(p, g, v):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = (vr[..., None] * vc[..., None, :]) / jnp.maximum(
                jnp.mean(vr, axis=-1, keepdims=True)[..., None], 1e-30)
            update = g / jnp.sqrt(denom + 1e-30)
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": beta2 * v["v"] + (1 - beta2) * g2}
            update = g / jnp.sqrt(nv["v"] + 1e-30)
        # Update clipping (RMS <= 1) per Adafactor.
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), nv

    leaves, treedef = jax.tree.flatten(params)
    gleaves = treedef.flatten_up_to(grads)
    vleaves = treedef.flatten_up_to(state["v"])
    new = [upd(p, g, v) for p, g, v in zip(leaves, gleaves, vleaves)]
    new_p = treedef.unflatten([n[0] for n in new])
    new_v = treedef.unflatten([n[1] for n in new])
    return new_p, {"v": new_v}, lr


def opt_init(cfg: OptimizerConfig, params):
    return {"adamw": adamw_init, "adafactor": adafactor_init}[cfg.name](params)


def opt_update(cfg: OptimizerConfig, params, grads, state, step):
    fn = {"adamw": adamw_update, "adafactor": adafactor_update}[cfg.name]
    return fn(cfg, params, grads, state, step)


def opt_state_specs(cfg: OptimizerConfig, param_specs):
    """Optimizer-state PartitionSpecs mirroring the parameter specs."""
    from jax.sharding import PartitionSpec as P

    if cfg.name == "adamw":
        return {"mu": param_specs, "nu": param_specs}
    # adafactor: factored moments drop one axis of the spec.
    def fac_spec(spec):
        parts = tuple(spec) if spec is not None else ()
        def pad(t):
            return P(*t) if t else P()
        return {
            "vr": pad(parts[:-1]),
            "vc": pad(parts[:-2] + parts[-1:] if len(parts) >= 2 else parts),
        }

    def leaf_spec(spec, leafdict=None):
        return fac_spec(spec)

    return {"v": jax.tree.map(leaf_spec, param_specs,
                              is_leaf=lambda x: isinstance(x, type(P())))}
