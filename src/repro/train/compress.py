"""Int8 gradient compression with stochastic rounding for the cross-pod
gradient reduction.

Motivation (DESIGN.md §4): the ``pod`` axis crosses the data-center network
(DCN), which is an order of magnitude slower than intra-pod ICI.  The
gradient all-reduce over ``pod`` is the only cross-pod collective in the
training step; quantising it 4x (f32->int8 blocks with per-block scales)
cuts the dominant cross-pod roofline term proportionally.

Implementation: psum the int8-quantised gradients over the ``pod`` axis only
(stochastic rounding keeps the estimator unbiased), then do the intra-pod
reduction at full precision.  Exposed as a drop-in wrapper around the grad
pytree inside ``shard_map``-style manual-collective train steps, and as a
pure quantise/dequantise pair for testing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x: jnp.ndarray, rng: jax.Array):
    """Blockwise int8 quantisation with stochastic rounding.

    Returns (q int8[N], scale f32[ceil(N/BLOCK)]). Unbiased: E[dequant] = x.
    """
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    y = blocks / scale[:, None]
    noise = jax.random.uniform(rng, y.shape)
    q = jnp.floor(y + noise).clip(-127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype):
    y = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return y.reshape(-1)[:n].reshape(shape).astype(dtype)


def compressed_pmean(tree, axis_name: str, rng: jax.Array):
    """Mean-reduce a gradient pytree over ``axis_name`` with int8 payload.

    Two-phase shared-scale scheme:
      1. per-block max magnitudes are max-reduced across the axis (tiny
         payload) so every participant quantises against the same scale;
      2. stochastically-rounded int8 payloads are sum-reduced (int32 accum)
         and dequantised once.
    Unbiased (E[result] = true mean); payload is ~4x smaller than f32.
    Must run inside a ``shard_map``/``pmap`` context binding ``axis_name``.
    """
    leaves, treedef = jax.tree.flatten(tree)
    rngs = jax.random.split(rng, max(len(leaves), 1))
    n_dev = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    out = []
    for leaf, r in zip(leaves, rngs):
        flat = leaf.astype(jnp.float32).reshape(-1)
        pad = (-flat.shape[0]) % BLOCK
        blocks = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
        local_max = jnp.max(jnp.abs(blocks), axis=1)
        shared_max = jax.lax.pmax(local_max, axis_name)
        scale = jnp.maximum(shared_max / 127.0, 1e-30)
        y = blocks / scale[:, None]
        noise = jax.random.uniform(r, y.shape)
        q = jnp.floor(y + noise).clip(-127, 127).astype(jnp.int8)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        deq = (qsum.astype(jnp.float32) * scale[:, None]).reshape(-1)[
            : flat.shape[0]].reshape(leaf.shape)
        out.append((deq / n_dev).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)
