"""Training substrate: optimizers, train step, gradient compression."""

from repro.train.optimizer import OptimizerConfig
from repro.train.step import init_state, jit_train_step, make_train_step, state_specs
