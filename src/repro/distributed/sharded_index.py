"""The ``"sharded-indexed"`` join driver: the sub-quadratic inverted-index
candidate path (:mod:`repro.index`) composed with the device mesh.

The ring driver shards the *dense grid*: every device still bitmap-evaluates
its |R|/n × |S| slice, so adding devices divides quadratic work without
changing its asymptotics.  This driver shards the *index* instead — the
established route to distributed set-similarity joins (cf. the MapReduce
filter-and-verification-tree R-S join and Christiani et al.'s scalable set
similarity join in PAPERS.md):

* **Build** — the corpus-side CSR postings index is cut into contiguous
  frequency-ordered *token slabs*, one per device, balanced by postings
  volume (:func:`repro.index.postings.partition_postings`, cached on the
  :class:`~repro.core.engine.PreparedCollection` with a
  ``builds["sharded_postings"]`` counter).  Every device also holds the full
  R token/length/bitmap arrays — verification is row-local, only candidate
  *generation* is sharded.
* **Probe** — probe chunks are broadcast (replicated) into one ``shard_map``
  step per chunk; each device runs the *same* traced stages as the
  single-device indexed driver (:func:`repro.index.candidates.
  expand_and_filter` → :func:`~repro.index.candidates.dedup_pairs`) against
  its slab: the sentinel-padded slab arrays make out-of-slab tokens expand
  to nothing, so per-shard expansions partition the global expansion
  exactly.
* **Reduce** — a capacity-aware allgather-compact: per-shard survivor
  buffers are ``all_gather``-ed, *globally* re-deduplicated (the same
  ``dedup_pairs`` stage — a pair generated via tokens on two different
  slabs must count once), and each device takes an equal ``cap``-slot slice
  of the compacted unique list for bitmap verdict + exact verification
  (:func:`~repro.index.candidates.verdict_and_verify`).  The slice split
  rebalances verification even when one slab is hot, and makes the
  per-shard funnel counters *sum* to the single-device driver's counters
  bit for bit.
* **Escalate** — the overflow contract is the single-device driver's,
  preserved: a chunk whose exact host-prepass expansion exceeds a forced
  ``capacity`` (or the auto-capacity ceiling) is re-run on the dense grid
  path and recorded in ``JoinStats.overflow_blocks``.  The trigger is the
  *total* chunk expansion — identical to the indexed driver's — so the
  sharded funnel stays bit-identical to the single-device one under any
  capacity (the conformance acceptance bar).

``JoinStats`` is the sum of the per-device funnel counters
(``postings_expanded`` / ``candidates_generated`` / ``candidates`` /
``verified_true``), which the shard-count-invariance test pins to the
single-device indexed driver's stats for 1/2/4/8 shards.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core import expected, verify
from repro.core.collection import Collection, split_join_args
from repro.core.constants import BITMAP_COMBINED, JACCARD, PAD_TOKEN
from repro.core.engine import PreparedCollection, as_prepared
from repro.core.join import JoinStats, _bucket_capacity
from repro.distributed.sharding import join_axes
from repro.index.candidates import (
    _MAX_AUTO_CAPACITY,
    _dense_chunk_fallback,
    _pad_chunk,
    dedup_pairs,
    expand_and_filter,
    finish_pairs,
    probe_prefix_lengths,
    verdict_and_verify,
)
from repro.index.postings import shard_expansion_counts


_SHARDED_ENTRYPOINTS = None


def _sharded_entrypoint_cache():
    """The sharded driver's traced-factory cache — a
    :class:`repro.serve.entrypoints.EntrypointCache` (lazy import: the serve
    package imports the engine)."""
    global _SHARDED_ENTRYPOINTS
    if _SHARDED_ENTRYPOINTS is None:
        from repro.serve.entrypoints import EntrypointCache
        _SHARDED_ENTRYPOINTS = EntrypointCache(maxsize=256)
    return _SHARDED_ENTRYPOINTS


def _sharded_chunk_fn(mesh, axes, *, sim: str, tau: float, cap: int, lp: int,
                      scale: int, self_join: bool, cutoff: int, impl: str):
    """Memoized traced factory for the per-chunk shard_map step: repeated
    probes — and the conformance sweep — reuse compiled executables instead
    of re-tracing a fresh ``shard_map`` closure per call (the jit cache then
    keys on input shapes as usual)."""
    key = ("sharded_chunk", mesh, axes, sim, tau, cap, lp, scale, self_join,
           cutoff, impl)
    return _sharded_entrypoint_cache().get(
        key, lambda: _build_sharded_chunk_fn(
            mesh, axes, sim=sim, tau=tau, cap=cap, lp=lp, scale=scale,
            self_join=self_join, cutoff=cutoff, impl=impl))


def _build_sharded_chunk_fn(mesh, axes, *, sim: str, tau: float, cap: int,
                            lp: int, scale: int, self_join: bool, cutoff: int,
                            impl: str):
    """Compile (once per static config) the per-chunk shard_map step.

    The returned jitted callable runs stage 1+2 per slab, the
    allgather-compact reduce, and stage 3 on each device's slice of the
    globally deduped candidate list.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis_name = axes if len(axes) > 1 else axes[0]
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))

    def local(post_set, post_pos, post_len, post_key, vocab, vocab_tid,
              tokens_r, lengths_r, words_r,
              probe_tokens, probe_lengths, probe_words, probe_prefix,
              lo_r, hi_r, need_tab, s0):
        # Slab arrays arrive (1, pmax): drop the shard dim.
        post_set, post_pos, post_len, post_key = (
            post_set[0], post_pos[0], post_len[0], post_key[0])
        my = jnp.int32(0)
        for a in axes:  # row-major rank along the (possibly composite) axes
            my = my * mesh.shape[a] + jax.lax.axis_index(a)

        # Stages 1+2 on my token slab (identical code to the single-device
        # chunk step; the slab view owns a subset of the tokens).
        rr, ss, n_exp = expand_and_filter(
            post_set, post_pos, post_len, post_key, vocab, vocab_tid,
            probe_tokens, probe_lengths, probe_prefix, lo_r, hi_r, s0,
            sim=sim, tau=tau, cap=cap, lp=lp, scale=scale,
            self_join=self_join, impl=impl)
        cand_r, cand_s, _n_local = dedup_pairs(rr, ss, cap)

        # Allgather-compact reduce: every device re-deduplicates the union
        # (a pair reachable via two slabs must count once), then takes an
        # equal slice of the unique list — verification is rebalanced
        # across the mesh regardless of slab skew.
        g_r = jax.lax.all_gather(cand_r, axis_name).reshape(-1)
        g_s = jax.lax.all_gather(cand_s, axis_name).reshape(-1)
        u_r, u_s, n_gen = dedup_pairs(g_r, g_s, n_dev * cap)
        start = my * cap
        sl_r = jax.lax.dynamic_slice(u_r, (start,), (cap,))
        sl_s = jax.lax.dynamic_slice(u_s, (start,), (cap,))
        slot_ok = (start + jnp.arange(cap, dtype=jnp.int32)) < n_gen
        n_slice = jnp.sum(slot_ok, dtype=jnp.int32)

        # Stage 3 on my slice (full R arrays are replicated: verification
        # is row-local).
        pairs, n_bm, n_ok = verdict_and_verify(
            tokens_r, lengths_r, words_r, probe_tokens, probe_lengths,
            probe_words, sl_r, sl_s, slot_ok, need_tab, s0,
            sim=sim, tau=tau, cutoff=cutoff, impl=impl)
        counters = jnp.stack([n_exp, n_slice, n_bm, n_ok])[None]  # (1, 4)
        return pairs, counters

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axes),) * 4 + (P(),) * 13,
        out_specs=(P(axes), P(axes)),
        check_rep=False,
    )
    return jax.jit(fn)


def sharded_indexed_join_prepared(
    prep_r: PreparedCollection,
    prep_s: PreparedCollection | None = None,
    *,
    mesh,
    axis=None,
    sim: str = JACCARD,
    tau: float = 0.8,
    b: int = 128,
    method: str = BITMAP_COMBINED,
    mix: bool = False,
    ell: int = 1,
    probe_block: int = 4096,
    impl: str = "auto",
    use_cutoff: bool = True,
    capacity: int | None = None,
    return_stats: bool = False,
):
    """Index-driven exact join sharded over a device mesh.

    The drop-in mesh twin of :func:`repro.index.candidates.
    indexed_join_prepared`: same knobs plus ``mesh``/``axis`` (``axis=None``
    shards over all mesh axes), same self-join contract (self-join ONLY when
    ``prep_s`` is omitted), same return shape, and — by construction — the
    bit-identical pair set *and* summed ``JoinStats`` for any shard count,
    probe block and capacity.

    ``capacity`` bounds each device's buffers; a chunk whose exact total
    expansion exceeds it escalates to the dense grid path
    (``JoinStats.overflow_blocks``), the same per-(device, chunk) contract
    and the same trigger as the single-device driver, so forced-overflow
    runs stay conformant too.
    """
    axes, _axis_name, n_dev = join_axes(mesh, axis)
    self_join = prep_s is None
    if self_join:
        prep_s = prep_r
    chosen = bm.choose_method(tau, b) if method == BITMAP_COMBINED else method
    cutoff = (expected.cutoff_point(chosen, b, float(tau)) if use_cutoff
              else 1 << 30)
    nr, ns = prep_r.num_sets, prep_s.num_sets
    stats = JoinStats()

    def _finish(pairs_list):
        pairs = finish_pairs(prep_r, prep_s, self_join, pairs_list)
        return (pairs, stats) if return_stats else pairs

    sharded = prep_r.sharded_postings(sim, tau, ell, n_dev)
    post = sharded.base
    ps_np, lp = probe_prefix_lengths(prep_s, sim, tau)
    if nr == 0 or ns == 0 or post.num_postings == 0 or lp == 0:
        return _finish([])

    tokens_r, lengths_r = prep_r.device_arrays()
    words_r = prep_r.bitmap_words(b, chosen, mix=mix)
    if self_join:
        tokens_s, lengths_s, words_s = tokens_r, lengths_r, words_r
    else:
        tokens_s, lengths_s = prep_s.device_arrays()
        words_s = prep_s.bitmap_words(b, chosen, mix=mix)
    lo_np, hi_np, lo_d, hi_d = prep_s.length_window_int(sim, tau)
    ps_d = jnp.asarray(ps_np)
    slabs = sharded.device_arrays()
    vocab_d, tid_d = post.device_arrays()[:2]
    scale = post.max_len + 1
    need_tab = verify.min_overlap_table_dev(
        sim, float(tau), prep_r.max_len, prep_s.max_len)

    cb = int(probe_block)
    pairs_out: list[np.ndarray] = []
    for c0 in range(0, ns, cb):
        c1 = min(c0 + cb, ns)
        stats.blocks_total += 1
        per_shard = shard_expansion_counts(
            sharded, prep_s.tokens[c0:c1], ps_np[c0:c1],
            lo_np[c0:c1], hi_np[c0:c1], lp)
        n_exp = int(per_shard.sum())
        stats.postings_expanded += n_exp
        if n_exp == 0:
            stats.blocks_skipped += 1
            continue
        if capacity is None:
            cap = min(_bucket_capacity(int(per_shard.max())),
                      nr * (c1 - c0) * lp)
        else:
            cap = int(capacity)
        if (capacity is not None and n_exp > cap) or n_exp > _MAX_AUTO_CAPACITY:
            # Escalation trigger == the single-device driver's (total chunk
            # expansion vs the forced capacity / auto ceiling): the funnel
            # stays bit-identical under overflow, and no per-shard buffer
            # can silently truncate on the fast path (shard counts are
            # bounded by the total).
            stats.overflow_blocks += 1
            n_win, n_bm, vpairs = _dense_chunk_fallback(
                tokens_r, lengths_r, words_r,
                tokens_s[c0:c1], lengths_s[c0:c1], words_s[c0:c1],
                np.asarray(lo_d[c0:c1]), np.asarray(hi_d[c0:c1]), c0,
                sim=sim, tau=tau, cutoff=cutoff, impl=impl,
                self_join=self_join)
            stats.total_pairs += n_win
            stats.candidates_generated += n_win
            stats.candidates += n_bm
            stats.verified_true += len(vpairs)
            if len(vpairs):
                pairs_out.append(vpairs)
            continue
        step = _sharded_chunk_fn(
            mesh, axes, sim=sim, tau=float(tau), cap=cap, lp=lp, scale=scale,
            self_join=self_join, cutoff=int(cutoff), impl=impl)
        pairs_d, counters_d = step(
            *slabs, vocab_d, tid_d, tokens_r, lengths_r, words_r,
            _pad_chunk(tokens_s[c0:c1], cb, PAD_TOKEN),
            _pad_chunk(lengths_s[c0:c1], cb, 0),
            _pad_chunk(words_s[c0:c1], cb, 0),
            _pad_chunk(ps_d[c0:c1], cb, 0),
            _pad_chunk(lo_d[c0:c1], cb, 0), _pad_chunk(hi_d[c0:c1], cb, 0),
            need_tab, jnp.int32(c0))
        counters = np.asarray(counters_d)  # (n_dev, 4)
        pairs_np = np.asarray(pairs_d).reshape(n_dev, cap, 2)
        # Summed per-shard funnel == the single-device chunk counters: the
        # slab expansions partition the chunk's, the slice counts partition
        # the globally deduped candidate list.
        stats.total_pairs += int(counters[:, 1].sum())
        stats.candidates_generated += int(counters[:, 1].sum())
        stats.candidates += int(counters[:, 2].sum())
        stats.verified_true += int(counters[:, 3].sum())
        for d in range(n_dev):
            k = int(counters[d, 3])
            if k:
                pairs_out.append(pairs_np[d, :k].astype(np.int64))

    return _finish(pairs_out)


def sharded_indexed_bitmap_join(
    col_r: Collection | PreparedCollection,
    col_s: Collection | PreparedCollection | str | None = None,
    sim: str = JACCARD,
    tau: float = 0.8,
    *,
    mesh,
    axis=None,
    **kwargs,
):
    """Collection-level wrapper of :func:`sharded_indexed_join_prepared`
    (the ``blocked_bitmap_join`` calling convention; plain collections are
    prepared on the spot, prepared ones reuse their caches — including the
    sharded postings slabs)."""
    col_s, sim, tau = split_join_args(col_s, sim, tau)
    return sharded_indexed_join_prepared(
        as_prepared(col_r), None if col_s is None else as_prepared(col_s),
        mesh=mesh, axis=axis, sim=sim, tau=tau, **kwargs)
