"""Distributed runtime: sharded checkpointing, fault tolerance, elasticity."""

from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault import FaultTolerantRunner, RunnerConfig
