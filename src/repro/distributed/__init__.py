"""Distributed runtime: sharded checkpointing, fault tolerance, elasticity,
and the mesh-sharded inverted-index join driver (``"sharded-indexed"``,
:mod:`repro.distributed.sharded_index`)."""

from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault import FaultTolerantRunner, RunnerConfig
from repro.distributed.sharded_index import (
    sharded_indexed_bitmap_join,
    sharded_indexed_join_prepared,
)
