"""Fault tolerance + straggler mitigation runner.

At 1000+ nodes the mean time between node failures drops below the length of
a training run, so the framework treats failure as the normal case:

* **Checkpoint/restart loop** — :class:`FaultTolerantRunner` drives a step
  function; any step may raise (simulating a device/host loss); the runner
  restores the latest complete checkpoint and resumes.  With
  ``elastic=True`` the restore may land on a *different* mesh (the
  checkpoint layer reshards on read), covering scale-down restarts when a
  replacement pod is not immediately available.
* **Straggler mitigation** — a deadline monitor tracks per-step wall time
  against a rolling median; steps slower than ``straggler_factor`` x median
  are flagged, and the policy hook decides between (a) logging, (b) marking
  the slow host for exclusion at the next restart (the elastic path), or
  (c) re-issuing input shards (for data-pipeline stragglers).  In this
  single-process container the detection logic is fully exercised by tests
  via injected delays; the exclusion action is a mesh-shrink restart, which
  is real (see tests/test_fault.py).

This is deliberately synchronous-SPMD-shaped (like real TPU pods): there is
no async parameter server; recovery = restore + rerun, and the only state
that must survive is the checkpoint + data-pipeline cursor.
"""

from __future__ import annotations

import dataclasses
import logging
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

from repro.distributed.checkpoint import CheckpointManager

log = logging.getLogger(__name__)


@dataclasses.dataclass
class FaultEvent:
    step: int
    kind: str           # "failure" | "straggler" | "restore"
    detail: str = ""


@dataclasses.dataclass
class RunnerConfig:
    checkpoint_every: int = 50
    async_checkpoint: bool = True
    max_restarts: int = 10
    straggler_factor: float = 3.0
    straggler_window: int = 16


class FaultTolerantRunner:
    """Drives ``step_fn(state, batch) -> (state, metrics)`` with recovery.

    ``make_state(mesh) -> (state, shardings)`` rebuilds/loads the state —
    called at start and after every failure (possibly with a new mesh from
    ``remesh()``, the elastic path).
    """

    def __init__(
        self,
        step_fn: Callable,
        make_state: Callable,
        batch_iter,
        ckpt: CheckpointManager,
        cfg: RunnerConfig = RunnerConfig(),
        remesh: Optional[Callable[[], Any]] = None,
    ):
        self.step_fn = step_fn
        self.make_state = make_state
        self.batch_iter = batch_iter
        self.ckpt = ckpt
        self.cfg = cfg
        self.remesh = remesh
        self.events: List[FaultEvent] = []
        self.step_times: List[float] = []

    def _check_straggler(self, step: int, dt: float) -> None:
        w = self.step_times[-self.cfg.straggler_window:]
        if len(w) >= 4:
            med = statistics.median(w)
            if dt > self.cfg.straggler_factor * med:
                self.events.append(FaultEvent(step, "straggler",
                                              f"{dt:.3f}s vs median {med:.3f}s"))
                log.warning("straggler at step %d: %.3fs (median %.3fs)", step, dt, med)
        self.step_times.append(dt)

    def run(self, num_steps: int) -> Dict[str, Any]:
        restarts = 0
        state, shardings = self.make_state(self.remesh() if self.remesh else None)
        # Resume from the latest checkpoint if one exists.
        if self.ckpt.latest_step() is not None:
            state, at = self._restore(shardings, state)
            self.events.append(FaultEvent(at, "restore", "startup resume"))

        step = int(jax_device_get(state["step"])) if "step" in state else 0
        while step < num_steps:
            batch = next(self.batch_iter)
            t0 = time.monotonic()
            try:
                state, metrics = self.step_fn(state, batch)
            except Exception as e:  # noqa: BLE001 — any device loss surfaces here
                restarts += 1
                self.events.append(FaultEvent(step, "failure", repr(e)))
                if restarts > self.cfg.max_restarts:
                    raise
                log.warning("step %d failed (%s); restoring", step, e)
                self.ckpt.wait()
                mesh = self.remesh() if self.remesh else None
                state, shardings = self.make_state(mesh)
                state, at = self._restore(shardings, state)
                self.events.append(FaultEvent(at, "restore", f"after failure at {step}"))
                step = at
                continue
            self._check_straggler(step, time.monotonic() - t0)
            step += 1
            if step % self.cfg.checkpoint_every == 0:
                if self.cfg.async_checkpoint:
                    self.ckpt.save_async(step, state)
                else:
                    self.ckpt.save(step, state)
        self.ckpt.wait()
        self.ckpt.save(step, state)
        return {"state": state, "events": self.events, "restarts": restarts}

    def _restore(self, shardings, state_like):
        import jax

        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state_like)
        state, at = self.ckpt.restore(shapes, shardings)
        return state, at


def jax_device_get(x):
    import jax

    return jax.device_get(x)
