"""Sharded, elastic, async-capable checkpointing.

Layout on disk::

    <dir>/step_000123/
        MANIFEST.json       # tree structure, shapes, dtypes, commit marker
        leaf_00000.npy ...  # one .npy per pytree leaf

Guarantees:

* **Atomic commit** — writes land in ``step_X.tmp/`` and are renamed into
  place; a crash mid-save never corrupts the latest complete checkpoint
  (restore picks the newest directory containing a MANIFEST).
* **Elastic restore** — leaves are loaded through
  ``jax.make_array_from_callback`` with the *target* sharding, memmap-slicing
  only the bytes each device needs; the saving and restoring meshes may
  differ in shape and size (scale-up/scale-down restart).
* **Async save** — ``save_async`` snapshots to host memory synchronously
  (cheap) and writes in a background thread, overlapping I/O with training;
  ``wait()`` joins before the next save.

In this single-process container each leaf is written whole; on a real
multi-host deployment the same manifest format holds per-process shard files
(each host writes its addressable shards) — the restore path is already
slice-based and would not change.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state) -> str:
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        return self._write(step, host_state)

    def save_async(self, step: int, state) -> None:
        """Snapshot to host then write in the background."""
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(x), state)  # sync snapshot
        self._thread = threading.Thread(target=self._write, args=(step, host_state))
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state) -> str:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _flatten_with_names(host_state)
        manifest = {"step": step, "leaves": []}
        for i, (name, leaf) in enumerate(leaves):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), leaf)
            manifest["leaves"].append(
                {"name": name, "file": fname, "shape": list(np.shape(leaf)),
                 "dtype": str(np.asarray(leaf).dtype)})
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in sorted(os.listdir(self.directory)):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.directory, d, "MANIFEST.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_shapes, shardings=None, step: Optional[int] = None):
        """Restore into the given tree structure.

        ``shardings``: optional matching tree of ``NamedSharding`` — enables
        elastic restore onto any mesh (each device reads only its slice via
        memmap).  Without it, full host arrays are returned.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        files = {e["name"]: e for e in manifest["leaves"]}

        names = [n for n, _ in _flatten_with_names(state_shapes)]
        leaves_shapes = jax.tree.leaves(state_shapes)
        shard_leaves = jax.tree.leaves(shardings) if shardings is not None else [None] * len(names)
        out_leaves = []
        for name, shp, shd in zip(names, leaves_shapes, shard_leaves):
            entry = files[name]
            path = os.path.join(d, entry["file"])
            arr = np.load(path, mmap_mode="r")
            if tuple(arr.shape) != tuple(shp.shape):
                raise ValueError(f"shape mismatch for {name}: {arr.shape} vs {shp.shape}")
            if shd is None:
                out_leaves.append(np.array(arr))
            else:
                out_leaves.append(jax.make_array_from_callback(
                    tuple(shp.shape), shd, lambda idx, a=arr: np.asarray(a[idx])))
        treedef = jax.tree.structure(state_shapes)
        return jax.tree.unflatten(treedef, out_leaves), step
