"""Activation sharding constraints, mesh-agnostic.

Model code stays free of mesh objects: it annotates activations with logical
dim roles ("batch" / "tp" / None) via :func:`constrain`; the launcher binds a
mesh with :func:`activation_sharding`.  Without an active context the calls
are no-ops (unit tests, single-device runs).

Why: XLA's sharding propagation is good but not clairvoyant through deep
``while`` nests (layer scan x flash-attention scans).  Pinning the batch axis
on the per-layer activations and the head/ff axes at projection outputs keeps
every loop body sharded the way the top-level specs intend.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Sequence, Tuple

import numpy as np

_TLS = threading.local()


def join_axes(mesh, axis=None):
    """Resolve a mesh + axis spec for the distributed join drivers.

    ``axis`` may be a single axis name, a tuple of names, or ``None`` (all
    of the mesh's axes).  Returns ``(axes, axis_name, n_dev)``: the
    normalized axes tuple, the name to hand to collectives (the tuple
    itself when composite, the bare string otherwise — what
    ``ppermute``/``all_gather``/``axis_index`` expect), and the device
    count along those axes.  Shared by ``ring_join*`` and the
    ``sharded-indexed`` driver so every mesh consumer normalizes the same
    way.
    """
    if axis is None:
        axes = tuple(mesh.axis_names)
    elif isinstance(axis, str):
        axes = (axis,)
    else:
        axes = tuple(axis)
    for a in axes:
        if a not in mesh.shape:
            raise ValueError(f"axis {a!r} not in mesh axes {mesh.axis_names}")
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))
    axis_name = axes if len(axes) > 1 else axes[0]
    return axes, axis_name, n_dev


@contextmanager
def activation_sharding(mesh, batch_axes: Tuple[str, ...] = ("pod", "data"),
                        tp_axis: str = "model", seq_parallel: bool = False):
    """seq_parallel: shard the *sequence* dim of the residual stream over the
    TP axis between blocks (Megatron-SP). Turns the per-layer dx all-reduces
    into reduce-scatter/all-gather pairs and shards norm/elementwise work."""
    prev = getattr(_TLS, "ctx", None)
    baxes = tuple(a for a in batch_axes if a in mesh.shape)
    tp = tp_axis if tp_axis in mesh.shape else None
    _TLS.ctx = (mesh, baxes, tp, bool(seq_parallel))
    try:
        yield
    finally:
        _TLS.ctx = prev


def current_context():
    return getattr(_TLS, "ctx", None)


def constrain(x, dims: Sequence[Optional[str]]):
    """Apply with_sharding_constraint according to logical dim roles.

    dims: per-axis role — "batch" (shard over the batch axes), "tp" (shard
    over the model axis), or None (replicate).  Divisibility is checked; a
    non-divisible dim silently replicates (e.g. 3 KV heads on a 16-way TP
    axis).
    """
    ctx = current_context()
    if ctx is None:
        return x
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh, baxes, tp = ctx[0], ctx[1], ctx[2]
    bsize = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    spec = []
    for size, role in zip(x.shape, dims):
        if role == "batch" and baxes and size % bsize == 0:
            spec.append(baxes)
        elif role == "tp" and tp and size % mesh.shape[tp] == 0:
            spec.append(tp)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain_residual(x):
    """Between-block residual-stream constraint: (batch, [seq over TP], None).

    With ``seq_parallel`` enabled in the active context, dim 1 (sequence)
    shards over the TP axis when divisible; otherwise replicated (decode
    steps with S=1 fall back automatically).
    """
    ctx = current_context()
    if ctx is None:
        return x
    seq_par = len(ctx) > 3 and ctx[3]
    if seq_par and x.ndim >= 2:
        return constrain(x, ("batch", "tp") + (None,) * (x.ndim - 2))
    return constrain(x, ("batch",) + (None,) * (x.ndim - 1))


def attn_partition(q, k, v, num_heads: int, num_kv_heads: int):
    """Attention operand partitioning with a context-parallel fallback.

    * heads divisible by the TP axis: classic head-parallel q/k/v.
    * otherwise (e.g. 9 heads on a 16-way axis): shard the *q sequence* over
      TP with k/v replicated — every shard computes its slice of attention
      rows against the full K/V with no partial-sum collectives.  Without
      this, XLA shards the head_dim contraction and all-reduces every
      (q-block, kv-block) score tile (measured: 4.3e12 B/step on
      smollm prefill_32k).
    """
    ctx = current_context()
    if ctx is None:
        return q, k, v
    mesh, _, tp = ctx[0], ctx[1], ctx[2]
    if tp is None:
        return q, k, v
    tp_size = mesh.shape[tp]
    if num_kv_heads % tp_size == 0:
        q = constrain(q, ("batch", None, "tp", None))
        k = constrain(k, ("batch", None, "tp", None))
        v = constrain(v, ("batch", None, "tp", None))
    elif num_heads % tp_size == 0:
        q = constrain(q, ("batch", None, "tp", None))
        k = constrain(k, ("batch", None, None, None))
        v = constrain(v, ("batch", None, None, None))
    else:
        q = constrain(q, ("batch", "tp", None, None))
        k = constrain(k, ("batch", None, None, None))
        v = constrain(v, ("batch", None, None, None))
    return q, k, v
