"""Composable decoder LM covering all 10 assigned architectures.

One functional :class:`Model` wraps a :class:`ModelConfig` and provides:

* ``init(rng)`` / ``param_shapes()`` — parameter pytree (stacked-per-layer
  leaves so the layer stack lowers as a single ``lax.scan``);
* ``param_specs(mesh)`` — PartitionSpecs: FSDP over the batch axes
  (``("pod","data")``) on the largest non-model dim + tensor/expert parallel
  over ``model`` (heads / d_ff / experts / vocab), with divisibility-aware
  fallbacks (e.g. KV heads replicate when kv_heads < model-axis size);
* ``loss(params, batch)`` — next-token cross-entropy (+ MoE aux losses);
* ``prefill(params, batch)`` / ``decode_step(params, cache, batch)`` — the
  serving path with a per-layer KV / SSM-state cache.

Block schedules per family are documented in ``ModelConfig``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, constrain_residual
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig

Params = Dict[str, Any]


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------
    # Parameter construction
    # ------------------------------------------------------------------
    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        pdt = _dtype(cfg.param_dtype)
        keys = iter(jax.random.split(rng, 64))

        def dense(shape, scale_dim=None):
            scale = (scale_dim or shape[-2] if len(shape) >= 2 else shape[-1]) ** -0.5
            return (jax.random.normal(next(keys), shape, jnp.float32) * scale).astype(pdt)

        d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
        params: Params = {
            "embed": dense((v, d), scale_dim=d),
            "final_norm": jnp.ones((d,), pdt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense((d, v))

        def attn_params(n: int, cross: bool = False):
            p = {
                "wq": dense((n, d, cfg.attn_dim)),
                "wk": dense((n, d, cfg.kv_dim)),
                "wv": dense((n, d, cfg.kv_dim)),
                "wo": dense((n, cfg.attn_dim, d), scale_dim=cfg.attn_dim),
            }
            if cfg.qk_norm:
                p["q_norm"] = jnp.ones((n, cfg.head_dim), pdt)
                p["k_norm"] = jnp.ones((n, cfg.head_dim), pdt)
            return p

        def mlp_params(n: int, f: int):
            return {
                "w_gate": dense((n, d, f)),
                "w_up": dense((n, d, f)),
                "w_down": dense((n, f, d), scale_dim=f),
            }

        def moe_params(n: int):
            e, f = cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
            return {
                "router": dense((n, d, e)),
                "w_gate": dense((n, e, d, f)),
                "w_up": dense((n, e, d, f)),
                "w_down": dense((n, e, f, d), scale_dim=f),
            }

        def mamba_params(n: int):
            din, ns, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
            k = cfg.ssm_conv
            return {
                "w_z": dense((n, d, din)),
                "w_x": dense((n, d, din)),
                "w_b": dense((n, d, ns)),
                "w_c": dense((n, d, ns)),
                "w_dt": dense((n, d, h)),
                "conv_x": dense((n, k, din), scale_dim=k),
                "conv_b": dense((n, k, ns), scale_dim=k),
                "conv_c": dense((n, k, ns), scale_dim=k),
                "a_log": jnp.zeros((n, h), pdt),
                "dt_bias": jnp.zeros((n, h), pdt),
                "d_skip": jnp.ones((n, h), pdt),
                "norm": jnp.ones((n, din), pdt),
                "w_out": dense((n, din, d), scale_dim=din),
            }

        fam = cfg.family
        nl = cfg.num_layers
        if fam in ("dense", "audio"):
            params["blocks"] = {
                "attn_norm": jnp.ones((nl, d), pdt),
                "attn": attn_params(nl),
                "mlp_norm": jnp.ones((nl, d), pdt),
                "mlp": mlp_params(nl, ff),
            }
        elif fam == "moe":
            params["blocks"] = {
                "attn_norm": jnp.ones((nl, d), pdt),
                "attn": attn_params(nl),
                "mlp_norm": jnp.ones((nl, d), pdt),
                "moe": moe_params(nl),
            }
            if cfg.dense_residual:
                params["blocks"]["dense_mlp"] = mlp_params(nl, ff)
        elif fam == "ssm":
            params["blocks"] = {
                "norm": jnp.ones((nl, d), pdt),
                "mamba": mamba_params(nl),
            }
        elif fam == "hybrid":
            params["blocks"] = {
                "norm": jnp.ones((nl, d), pdt),
                "mamba": mamba_params(nl),
            }
            sa = attn_params(1)
            params["shared_attn"] = {
                "attn_norm": jnp.ones((1, d), pdt),
                "attn": sa,
                "mlp_norm": jnp.ones((1, d), pdt),
                "mlp": mlp_params(1, ff),
            }
        elif fam == "vlm":
            n_cross = cfg.num_layers // (cfg.cross_attn_every + 1)
            n_self = cfg.num_layers - n_cross
            assert n_self == n_cross * cfg.cross_attn_every, (
                "vlm layer count must decompose as n_cross * (cross_attn_every + 1)")
            params["blocks"] = {
                "attn_norm": jnp.ones((n_self, d), pdt),
                "attn": attn_params(n_self),
                "mlp_norm": jnp.ones((n_self, d), pdt),
                "mlp": mlp_params(n_self, ff),
            }
            params["cross_blocks"] = {
                "attn_norm": jnp.ones((n_cross, d), pdt),
                "attn": attn_params(n_cross, cross=True),
                "gate": jnp.zeros((n_cross,), pdt),
                "mlp_norm": jnp.ones((n_cross, d), pdt),
                "mlp": mlp_params(n_cross, ff),
            }
        else:
            raise ValueError(fam)
        return params

    def param_shapes(self) -> Params:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def num_params(self) -> int:
        return int(sum(np.prod(x.shape) for x in jax.tree.leaves(self.param_shapes())))

    def num_active_params(self) -> int:
        """Active parameters per token (MoE discounts inactive experts)."""
        cfg = self.cfg
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.param_shapes())[0]:
            size = int(np.prod(leaf.shape))
            keys = [getattr(k, "key", "") for k in path]
            if cfg.num_experts and any(k in ("w_gate", "w_up", "w_down") for k in keys) \
                    and "moe" in keys:
                size = size * cfg.experts_per_token // cfg.num_experts
            total += size
        return total

    # ------------------------------------------------------------------
    # Sharding
    # ------------------------------------------------------------------
    def param_specs(self, mesh, fsdp: Tuple[str, ...] = ("pod", "data"),
                    tp: str = "model") -> Params:
        """PartitionSpec tree matching ``param_shapes()``.

        Every matrix is TP-sharded over ``model`` on its "parallel" dim and
        FSDP-sharded over the batch axes on the opposite dim, with
        divisibility checks falling back to replication.
        """
        cfg = self.cfg
        fsdp = tuple(a for a in fsdp if a in mesh.shape)
        fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp])) if fsdp else 1
        tp_size = int(mesh.shape[tp]) if tp in mesh.shape else 1

        def ax_f(dim):  # FSDP axis if divisible
            return fsdp if fsdp and dim % fsdp_size == 0 else None

        def ax_t(dim):  # TP axis if divisible
            return tp if tp_size > 1 and dim % tp_size == 0 else None

        def mat(rows, cols, stacked=True, tp_on_cols=True):
            a, bdim = (rows, cols)
            if tp_on_cols:
                spec = (ax_f(a), ax_t(bdim))
            else:
                spec = (ax_t(a), ax_f(bdim))
            return P(*((None,) + spec if stacked else spec))

        shapes = self.param_shapes()

        def spec_for(path_keys, leaf) -> P:
            ks = path_keys
            shape = leaf.shape
            name = ks[-1]
            stacked = ks[0] in ("blocks", "cross_blocks", "shared_attn")
            if name == "embed":
                # Vocab-parallel (Megatron-style): V over TP so (a) lookups
                # psum a small (tokens, D) instead of all-gathering the table,
                # (b) the tied head yields vocab-sharded logits without the
                # (tokens, V) all-reduce.
                return P(ax_t(shape[0]), ax_f(shape[1]))
            if name == "lm_head":
                return P(ax_f(shape[0]), ax_t(shape[1]))
            if name == "final_norm":
                return P(None)
            s, body = (shape[1:], True) if stacked else (shape, False)

            def wrap(*spec):
                return P(*(((None,) + spec) if body else spec))

            if name in ("wq", "wk", "wv", "w_gate", "w_up", "w_z", "w_x",
                        "w_b", "w_c", "w_dt"):
                if len(s) == 3:  # MoE expert weights (E, D, F)
                    return wrap(ax_t(s[0]), ax_f(s[1]), None)
                return wrap(ax_f(s[0]), ax_t(s[1]))
            if name in ("wo", "w_down", "w_out"):
                if len(s) == 3:  # (E, F, D)
                    return wrap(ax_t(s[0]), None, ax_f(s[1]))
                return wrap(ax_t(s[0]), ax_f(s[1]))
            if name == "router":
                return wrap(ax_f(s[0]), None)
            if name == "conv_x":
                return wrap(None, ax_t(s[1]))
            if name in ("conv_b", "conv_c"):
                # N is tiny and shared across heads; sharding it makes the
                # SSD chunk quadratics partial-sum over `model` (huge psums).
                return wrap(None, None)
            if name in ("a_log", "dt_bias", "d_skip", "norm"):
                return wrap(ax_t(s[0]))
            if name in ("attn_norm", "mlp_norm", "q_norm", "k_norm"):
                return wrap(None)
            if name == "gate":
                return wrap() if len(s) == 0 else wrap(None)
            raise ValueError(f"no spec rule for {ks} {shape}")

        flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
        specs = [spec_for(tuple(getattr(k, "key", str(k)) for k in path), leaf)
                 for path, leaf in flat]
        return jax.tree_util.tree_unflatten(treedef, specs)

    # ------------------------------------------------------------------
    # Forward (training / prefill)
    # ------------------------------------------------------------------
    def _attn_mlp_body(self, x, blk, *, q_chunk=512, kv_chunk=512,
                       triangle=False, return_kv=False):
        cfg = self.cfg
        h = L.attention_block(
            constrain_residual(L.rms_norm(x, blk["attn_norm"], cfg.norm_eps)), blk["attn"],
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            qk_norm=cfg.qk_norm, norm_eps=cfg.norm_eps,
            q_chunk=q_chunk, kv_chunk=kv_chunk, triangle_schedule=triangle)
        x = constrain_residual(x + h)
        return x

    def _mlp(self, x, blk):
        cfg = self.cfg
        h = L.swiglu(constrain_residual(L.rms_norm(x, blk["mlp_norm"], cfg.norm_eps)),
                     blk["mlp"]["w_gate"], blk["mlp"]["w_up"], blk["mlp"]["w_down"])
        return constrain_residual(x + h)

    def forward(self, params: Params, batch: Dict[str, jnp.ndarray],
                *, triangle: bool = False) -> Tuple[jnp.ndarray, dict]:
        """Returns (logits (B, S, V), aux metrics)."""
        cfg = self.cfg
        cdt = _dtype(cfg.dtype)
        if cfg.frame_inputs:
            x = batch["frame_embeds"].astype(cdt)
        else:
            x = params["embed"].astype(cdt)[batch["tokens"]]
        x = constrain_residual(x)
        aux: dict = {}
        fam = cfg.family

        if fam in ("dense", "audio"):
            x = self._scan_dense(params["blocks"], x, triangle)
        elif fam == "moe":
            x, aux = self._scan_moe(params["blocks"], x, triangle)
        elif fam == "ssm":
            x = self._scan_ssm(params["blocks"], x)
        elif fam == "hybrid":
            x = self._scan_hybrid(params, x, triangle)
        elif fam == "vlm":
            x = self._scan_vlm(params, x, batch["image_embeds"].astype(cdt), triangle)
        else:
            raise ValueError(fam)

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdt))
        logits = constrain(logits, ("batch", None, "tp"))
        return logits, aux

    # --- per-family layer stacks (lax.scan over stacked params) ---

    def _maybe_remat(self, f):
        return jax.checkpoint(f, prevent_cse=False) if self.cfg.remat else f

    def _scan_dense(self, blocks, x, triangle):
        def body(x, blk):
            x = self._attn_mlp_body(x, blk, triangle=triangle)
            x = self._mlp(x, blk)
            return x, None

        x, _ = jax.lax.scan(self._maybe_remat(body), x, blocks)
        return x

    def _scan_moe(self, blocks, x, triangle):
        cfg = self.cfg

        def body(carry, blk):
            x, aux_acc = carry
            x = self._attn_mlp_body(x, blk, triangle=triangle)
            h = L.rms_norm(x, blk["mlp_norm"], cfg.norm_eps)
            mo, aux = moe_lib.moe_block(
                h, blk["moe"], num_experts=cfg.num_experts,
                k=cfg.experts_per_token, capacity_factor=cfg.capacity_factor)
            if cfg.dense_residual:
                mo = mo + L.swiglu(h, blk["dense_mlp"]["w_gate"],
                                   blk["dense_mlp"]["w_up"], blk["dense_mlp"]["w_down"])
            x = x + mo
            aux_acc = jax.tree.map(jnp.add, aux_acc,
                                   jax.tree.map(lambda v: v.astype(jnp.float32), aux))
            return (x, aux_acc), None

        aux0 = {"moe_aux_loss": jnp.float32(0), "moe_z_loss": jnp.float32(0),
                "moe_dropped": jnp.float32(0)}
        (x, aux), _ = jax.lax.scan(self._maybe_remat(body), (x, aux0), blocks)
        aux = jax.tree.map(lambda v: v / cfg.num_layers, aux)
        return x, aux

    def _ssm_body(self, x, blk):
        cfg = self.cfg
        h, _ = ssm_lib.mamba2_block(
            constrain_residual(L.rms_norm(x, blk["norm"], cfg.norm_eps)), blk["mamba"],
            d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
            chunk=cfg.ssm_chunk, norm_eps=cfg.norm_eps)
        return constrain_residual(x + h)

    def _scan_ssm(self, blocks, x):
        def body(x, blk):
            return self._ssm_body(x, blk), None

        x, _ = jax.lax.scan(self._maybe_remat(body), x, blocks)
        return x

    def _shared_attn_apply(self, shared, x, triangle):
        blk = jax.tree.map(lambda a: a[0], shared)
        x = self._attn_mlp_body(x, blk, triangle=triangle)
        x = self._mlp(x, blk)
        return x

    def _scan_hybrid(self, params, x, triangle):
        cfg = self.cfg
        nl, period = cfg.num_layers, cfg.attn_every
        n_groups, tail = nl // period, nl % period
        blocks = params["blocks"]
        main = jax.tree.map(lambda a: a[: n_groups * period].reshape(
            (n_groups, period) + a.shape[1:]), blocks)
        rest = jax.tree.map(lambda a: a[n_groups * period:], blocks)

        def group_body(x, grp):
            x = self._shared_attn_apply(params["shared_attn"], x, triangle)

            def layer_body(x, blk):
                return self._ssm_body(x, blk), None

            x, _ = jax.lax.scan(self._maybe_remat(layer_body), x, grp)
            return x, None

        x, _ = jax.lax.scan(group_body, x, main)
        if tail:
            def layer_body(x, blk):
                return self._ssm_body(x, blk), None

            x, _ = jax.lax.scan(self._maybe_remat(layer_body), x, rest)
        return x

    def _scan_vlm(self, params, x, image_embeds, triangle):
        cfg = self.cfg
        blocks, cross = params["blocks"], params["cross_blocks"]
        n_cross = jax.tree.leaves(cross)[0].shape[0]
        per = cfg.cross_attn_every
        self_grouped = jax.tree.map(
            lambda a: a.reshape((n_cross, per) + a.shape[1:]), blocks)

        def cross_body(x, cblk):
            b, s, _ = x.shape
            h = L.rms_norm(x, cblk["attn_norm"], cfg.norm_eps)
            ni = image_embeds.shape[1]
            kvh, hd = cfg.num_kv_heads, cfg.head_dim
            k = jnp.einsum("bnd,dq->bnq", image_embeds,
                           cblk["attn"]["wk"].astype(x.dtype)).reshape(b, ni, kvh, hd)
            v = jnp.einsum("bnd,dq->bnq", image_embeds,
                           cblk["attn"]["wv"].astype(x.dtype)).reshape(b, ni, kvh, hd)
            h = L.attention_block(
                h, cblk["attn"], num_heads=cfg.num_heads, num_kv_heads=kvh,
                head_dim=hd, rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                norm_eps=cfg.norm_eps, kv_override=(k, v))
            x = x + jnp.tanh(cblk["gate"]).astype(x.dtype) * h
            h2 = L.swiglu(L.rms_norm(x, cblk["mlp_norm"], cfg.norm_eps),
                          cblk["mlp"]["w_gate"], cblk["mlp"]["w_up"],
                          cblk["mlp"]["w_down"])
            return x + jnp.tanh(cblk["gate"]).astype(x.dtype) * h2

        def group_body(x, grp_and_cross):
            grp, cblk = grp_and_cross

            def layer_body(x, blk):
                x = self._attn_mlp_body(x, blk, triangle=triangle)
                x = self._mlp(x, blk)
                return x, None

            x, _ = jax.lax.scan(self._maybe_remat(layer_body), x, grp)
            x = cross_body(x, cblk)
            return x, None

        x, _ = jax.lax.scan(group_body, x, (self_grouped, cross))
        return x

    # ------------------------------------------------------------------
    # Loss
    # ------------------------------------------------------------------
    def loss(self, params: Params, batch: Dict[str, jnp.ndarray],
             *, triangle: bool = False) -> Tuple[jnp.ndarray, dict]:
        cfg = self.cfg
        logits, aux = self.forward(params, batch, triangle=triangle)
        logits = logits.astype(jnp.float32)
        labels = batch["labels"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        nll = logz - gold
        mask = batch.get("loss_mask")
        if mask is None:
            loss = jnp.mean(nll)
        else:
            loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        metrics = {"nll": loss, **aux}
        if "moe_aux_loss" in aux:
            loss = loss + cfg.aux_loss_coef * aux["moe_aux_loss"] \
                        + cfg.router_z_coef * aux["moe_z_loss"]
        metrics["loss"] = loss
        return loss, metrics
