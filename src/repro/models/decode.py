"""Serving path: KV / SSM-state caches, prefill and one-token decode.

``serve_step`` semantics per the assignment: decode shapes lower a single
new-token step against a cache of ``seq_len`` (``decode_32k``: B=128 cache
32k; ``long_500k``: B=1 cache 524k, SSM/hybrid only).

Cache sharding (see ``cache_specs``):
* KV caches shard KV-heads over ``model`` when divisible (else replicate) and
  batch over the batch axes;
* when the batch is too small to fill the batch axes (long_500k, B=1) the
  cache *sequence* dim shards over ``data`` instead — decode attention's
  softmax reductions then lower to the flash-style all-reduce pair
  (sequence-parallel decode).
* SSM states shard heads over ``model``; they are O(1) in sequence length,
  which is the whole point of running long_500k on the SSM/hybrid archs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.distributed.sharding import attn_partition, constrain
from repro.models.config import ModelConfig
from repro.models.model import Model, _dtype

Cache = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class DecodeEngine:
    model: Model

    @property
    def cfg(self) -> ModelConfig:
        return self.model.cfg

    # ------------------------------------------------------------------
    # Cache construction
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Cache:
        cfg = self.cfg
        cdt = _dtype(cfg.dtype)
        fam = cfg.family
        nl = cfg.num_layers

        def kv(n_layers):
            return {
                "k": jnp.zeros((n_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim), cdt),
                "v": jnp.zeros((n_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim), cdt),
            }

        def ssm_states(n_layers):
            din, ns, h, p = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
            k = cfg.ssm_conv
            return {
                "conv_x": jnp.zeros((n_layers, batch, k - 1, din), cdt),
                "conv_b": jnp.zeros((n_layers, batch, k - 1, ns), cdt),
                "conv_c": jnp.zeros((n_layers, batch, k - 1, ns), cdt),
                "ssm": jnp.zeros((n_layers, batch, h, p, ns), cdt),
            }

        cache: Cache = {"cur": jnp.zeros((batch,), jnp.int32)}
        if fam in ("dense", "audio", "moe"):
            cache.update(kv(nl))
        elif fam == "ssm":
            cache.update(ssm_states(nl))
        elif fam == "hybrid":
            n_groups = nl // cfg.attn_every
            cache.update(ssm_states(nl))
            cache["shared"] = kv(n_groups)
        elif fam == "vlm":
            n_cross = nl // (cfg.cross_attn_every + 1)
            n_self = nl - n_cross
            cache.update(kv(n_self))
            cache["img_k"] = jnp.zeros(
                (n_cross, batch, cfg.num_image_tokens, cfg.num_kv_heads, cfg.head_dim), cdt)
            cache["img_v"] = jnp.zeros_like(cache["img_k"])
        else:
            raise ValueError(fam)
        return cache

    def cache_shapes(self, batch: int, max_len: int) -> Cache:
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    def cache_specs(self, mesh, batch: int,
                    fsdp: Tuple[str, ...] = ("pod", "data"),
                    tp: str = "model") -> Cache:
        cfg = self.cfg
        fsdp = tuple(a for a in fsdp if a in mesh.shape)
        fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp])) if fsdp else 1
        tp_size = int(mesh.shape[tp]) if tp in mesh.shape else 1
        batch_ax = fsdp if fsdp and batch % fsdp_size == 0 else None
        # Sequence-parallel fallback for tiny batches (long_500k).
        seq_ax = None if batch_ax is not None else tuple(a for a in fsdp if a != "pod") or None

        def ax_t(dim):
            return tp if tp_size > 1 and dim % tp_size == 0 else None

        def spec_for(name, leaf):
            shape = leaf.shape
            if name == "cur":
                return P(None)
            if name in ("k", "v"):  # (L, B, S, KV, hd)
                sax = seq_ax if seq_ax and shape[2] % fsdp_size == 0 else None
                kv_ax = ax_t(shape[3])
                # MHA fallback (e.g. 24 KV heads on a 16-way model axis):
                # shard head_dim instead — decode attention contracts it and
                # psums small score tensors, keeping the cache 16x smaller.
                hd_ax = ax_t(shape[4]) if kv_ax is None else None
                return P(None, batch_ax, sax, kv_ax, hd_ax)
            if name in ("img_k", "img_v"):
                kv_ax = ax_t(shape[3])
                hd_ax = ax_t(shape[4]) if kv_ax is None else None
                return P(None, batch_ax, None, kv_ax, hd_ax)
            if name in ("conv_x", "conv_b", "conv_c"):
                return P(None, batch_ax, None, ax_t(shape[3]))
            if name == "ssm":  # (L, B, H, P, N)
                return P(None, batch_ax, ax_t(shape[2]), None, None)
            raise ValueError(name)

        shapes = self.cache_shapes(batch, 8)  # max_len placeholder; only dims matter

        def walk(tree, out):
            for k, vv in tree.items():
                if isinstance(vv, dict):
                    out[k] = walk(vv, {})
                else:
                    out[k] = spec_for(k, vv)
            return out

        # seq dim divisibility must use the real max_len
        shapes = self.cache_shapes(batch, max(fsdp_size, 8) * 64)
        return walk(shapes, {})

    # ------------------------------------------------------------------
    # Decode bodies
    # ------------------------------------------------------------------
    def _attn_decode(self, x, blk, kc, vc, cur):
        """x: (B, 1, D). kc/vc: (B, S, KV, hd). Returns (out, kc, vc)."""
        cfg = self.cfg
        b = x.shape[0]
        h = L.rms_norm(x, blk["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dq->bsq", h, blk["attn"]["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dq->bsq", h, blk["attn"]["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dq->bsq", h, blk["attn"]["wv"].astype(x.dtype))
        q = q.reshape(b, 1, cfg.num_heads, cfg.head_dim)
        k = k.reshape(b, 1, cfg.num_kv_heads, cfg.head_dim)
        v = v.reshape(b, 1, cfg.num_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = L.rms_norm(q, blk["attn"]["q_norm"], cfg.norm_eps)
            k = L.rms_norm(k, blk["attn"]["k_norm"], cfg.norm_eps)
        pos = cur[:, None]
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
        kc = kc.at[jnp.arange(b), cur].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[jnp.arange(b), cur].set(v[:, 0].astype(vc.dtype))
        out = L.decode_attention(q, kc, vc, cur + 1)
        out = out.reshape(b, 1, cfg.attn_dim)
        out = jnp.einsum("bsq,qd->bsd", out, blk["attn"]["wo"].astype(x.dtype))
        return x + out, kc, vc

    def _mlp_or_moe(self, x, blk):
        cfg = self.cfg
        h = L.rms_norm(x, blk["mlp_norm"], cfg.norm_eps)
        if "moe" in blk:
            mo, _ = moe_lib.moe_block(
                h, blk["moe"], num_experts=cfg.num_experts,
                k=cfg.experts_per_token, capacity_factor=cfg.capacity_factor)
            if cfg.dense_residual:
                mo = mo + L.swiglu(h, blk["dense_mlp"]["w_gate"],
                                   blk["dense_mlp"]["w_up"], blk["dense_mlp"]["w_down"])
            return x + mo
        return x + L.swiglu(h, blk["mlp"]["w_gate"], blk["mlp"]["w_up"],
                            blk["mlp"]["w_down"])

    def _mamba_decode(self, x, blk, lcache):
        cfg = self.cfg
        h, new_cache = ssm_lib.mamba2_block(
            L.rms_norm(x, blk["norm"], cfg.norm_eps), blk["mamba"],
            d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
            chunk=cfg.ssm_chunk, norm_eps=cfg.norm_eps, cache=lcache)
        return x + h, new_cache

    # ------------------------------------------------------------------
    # One-token decode step
    # ------------------------------------------------------------------
    def decode_step(self, params, cache: Cache,
                    batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Cache]:
        """batch: tokens (B, 1) (or frame_embeds (B, 1, D)). Returns
        (logits (B, 1, V), new cache)."""
        cfg = self.cfg
        cdt = _dtype(cfg.dtype)
        cur = cache["cur"]
        if cfg.frame_inputs:
            x = batch["frame_embeds"].astype(cdt)
        else:
            x = params["embed"].astype(cdt)[batch["tokens"]]
        fam = cfg.family
        new_cache = dict(cache)

        if fam in ("dense", "audio", "moe"):
            def body(x, scanned):
                blk, kc, vc = scanned
                x, kc, vc = self._attn_decode(x, blk, kc, vc, cur)
                x = self._mlp_or_moe(x, blk)
                return x, (kc, vc)

            x, (k_new, v_new) = jax.lax.scan(
                body, x, (params["blocks"], cache["k"], cache["v"]))
            new_cache["k"], new_cache["v"] = k_new, v_new

        elif fam == "ssm":
            def body(x, scanned):
                blk, lc = scanned
                x, nc = self._mamba_decode(x, blk, lc)
                return x, nc

            lcaches = {k: cache[k] for k in ("conv_x", "conv_b", "conv_c", "ssm")}
            x, ncs = jax.lax.scan(body, x, (params["blocks"], lcaches))
            new_cache.update(ncs)

        elif fam == "hybrid":
            nl, period = cfg.num_layers, cfg.attn_every
            n_groups, tail = nl // period, nl % period
            blocks = params["blocks"]
            lcaches = {k: cache[k] for k in ("conv_x", "conv_b", "conv_c", "ssm")}
            main_blk = jax.tree.map(
                lambda a: a[: n_groups * period].reshape((n_groups, period) + a.shape[1:]),
                blocks)
            main_cache = jax.tree.map(
                lambda a: a[: n_groups * period].reshape((n_groups, period) + a.shape[1:]),
                lcaches)
            tail_blk = jax.tree.map(lambda a: a[n_groups * period:], blocks)
            tail_cache = jax.tree.map(lambda a: a[n_groups * period:], lcaches)
            shared = jax.tree.map(lambda a: a[0], params["shared_attn"])

            def group_body(x, scanned):
                grp, grp_cache, kc, vc = scanned
                x, kc, vc = self._attn_decode(x, shared, kc, vc, cur)
                x = self._mlp(x, shared)

                def layer_body(x, sc):
                    blk, lc = sc
                    x, nc = self._mamba_decode(x, blk, lc)
                    return x, nc

                x, ncs = jax.lax.scan(layer_body, x, (grp, grp_cache))
                return x, (ncs, kc, vc)

            x, (main_ncs, k_new, v_new) = jax.lax.scan(
                group_body, x, (main_blk, main_cache,
                                cache["shared"]["k"], cache["shared"]["v"]))
            new_main = jax.tree.map(
                lambda a: a.reshape((n_groups * period,) + a.shape[2:]), main_ncs)
            if tail:
                def layer_body(x, sc):
                    blk, lc = sc
                    x, nc = self._mamba_decode(x, blk, lc)
                    return x, nc

                x, tail_ncs = jax.lax.scan(layer_body, x, (tail_blk, tail_cache))
                merged = jax.tree.map(
                    lambda m, t: jnp.concatenate([m, t], axis=0), new_main, tail_ncs)
            else:
                merged = new_main
            new_cache.update(merged)
            new_cache["shared"] = {"k": k_new, "v": v_new}

        elif fam == "vlm":
            n_cross = cfg.num_layers // (cfg.cross_attn_every + 1)
            per = cfg.cross_attn_every
            blocks = params["blocks"]
            grouped = jax.tree.map(
                lambda a: a.reshape((n_cross, per) + a.shape[1:]), blocks)
            kc_g = cache["k"].reshape((n_cross, per) + cache["k"].shape[1:])
            vc_g = cache["v"].reshape((n_cross, per) + cache["v"].shape[1:])

            def group_body(x, scanned):
                grp, cblk, kcs, vcs, ik, iv = scanned

                def layer_body(x, sc):
                    blk, kc, vc = sc
                    x, kc, vc = self._attn_decode(x, blk, kc, vc, cur)
                    x = self._mlp(x, blk)
                    return x, (kc, vc)

                x, (kcs, vcs) = jax.lax.scan(layer_body, x, (grp, kcs, vcs))
                # Cross-attention against cached image k/v.
                h = L.rms_norm(x, cblk["attn_norm"], cfg.norm_eps)
                b = x.shape[0]
                q = jnp.einsum("bsd,dq->bsq", h, cblk["attn"]["wq"].astype(x.dtype))
                q = q.reshape(b, 1, cfg.num_heads, cfg.head_dim)
                if cfg.qk_norm:
                    q = L.rms_norm(q, cblk["attn"]["q_norm"], cfg.norm_eps)
                n_img = ik.shape[1]
                out = L.decode_attention(
                    q, ik, iv, jnp.full((b,), n_img, jnp.int32))
                out = out.reshape(b, 1, cfg.attn_dim)
                out = jnp.einsum("bsq,qd->bsd", out, cblk["attn"]["wo"].astype(x.dtype))
                x = x + jnp.tanh(cblk["gate"]).astype(x.dtype) * out
                h2 = L.swiglu(L.rms_norm(x, cblk["mlp_norm"], cfg.norm_eps),
                              cblk["mlp"]["w_gate"], cblk["mlp"]["w_up"],
                              cblk["mlp"]["w_down"])
                x = x + jnp.tanh(cblk["gate"]).astype(x.dtype) * h2
                return x, (kcs, vcs)

            x, (k_new, v_new) = jax.lax.scan(
                group_body, x,
                (grouped, params["cross_blocks"], kc_g, vc_g,
                 cache["img_k"], cache["img_v"]))
            new_cache["k"] = k_new.reshape(cache["k"].shape)
            new_cache["v"] = v_new.reshape(cache["v"].shape)
        else:
            raise ValueError(fam)

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdt))
        logits = constrain(logits, ("batch", None, "tp"))
        new_cache["cur"] = cur + 1
        return logits, new_cache

    def _mlp(self, x, blk):
        cfg = self.cfg
        return x + L.swiglu(L.rms_norm(x, blk["mlp_norm"], cfg.norm_eps),
                            blk["mlp"]["w_gate"], blk["mlp"]["w_up"],
                            blk["mlp"]["w_down"])

    # ------------------------------------------------------------------
    # Prefill: forward pass that also fills the cache
    # ------------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, jnp.ndarray],
                max_len: Optional[int] = None,
                last_only: bool = False) -> Tuple[jnp.ndarray, Cache]:
        """Runs the full-sequence forward and returns (logits, filled cache).

        The cache is allocated at ``max_len`` (>= S) and filled for the first
        S positions. Prefill reuses the flash attention kernel schedule and
        additionally emits per-layer K/V as scan outputs.  ``last_only``
        returns logits for the final position only (B, 1, V) — what serving
        actually needs; avoids materialising the (B, S, V) tensor.
        """
        cfg = self.cfg
        cdt = _dtype(cfg.dtype)
        if cfg.frame_inputs:
            x = batch["frame_embeds"].astype(cdt)
        else:
            x = params["embed"].astype(cdt)[batch["tokens"]]
        b, s = x.shape[0], x.shape[1]
        max_len = max_len or s
        pad = max_len - s
        fam = cfg.family
        cache = self.init_cache(b, max_len)
        cache["cur"] = jnp.full((b,), s, jnp.int32)

        def kv_of(h, blk):
            k = jnp.einsum("bsd,dq->bsq", h, blk["attn"]["wk"].astype(h.dtype))
            v = jnp.einsum("bsd,dq->bsq", h, blk["attn"]["wv"].astype(h.dtype))
            k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
            v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
            return k, v

        def attn_with_cache(x, blk):
            h = L.rms_norm(x, blk["attn_norm"], cfg.norm_eps)
            q = jnp.einsum("bsd,dq->bsq", h, blk["attn"]["wq"].astype(x.dtype))
            q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
            k, v = kv_of(h, blk)
            if cfg.qk_norm:
                q = L.rms_norm(q, blk["attn"]["q_norm"], cfg.norm_eps)
                k = L.rms_norm(k, blk["attn"]["k_norm"], cfg.norm_eps)
            pos = jnp.arange(s)[None, :]
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
            q, k, v = attn_partition(q, k, v, cfg.num_heads, cfg.num_kv_heads)
            out = L.flash_attention(q, k, v, causal=True)
            out = out.reshape(b, s, cfg.attn_dim)
            x = x + jnp.einsum("bsq,qd->bsd", out, blk["attn"]["wo"].astype(x.dtype))
            kp = jnp.pad(k.astype(cdt), ((0, 0), (0, pad), (0, 0), (0, 0)))
            vp = jnp.pad(v.astype(cdt), ((0, 0), (0, pad), (0, 0), (0, 0)))
            return x, kp, vp

        if fam in ("dense", "audio", "moe"):
            def body(x, blk):
                x, kp, vp = attn_with_cache(x, blk)
                x = self._mlp_or_moe(x, blk)
                return x, (kp, vp)

            x, (kc, vc) = jax.lax.scan(body, x, params["blocks"])
            cache["k"], cache["v"] = kc, vc

        elif fam == "ssm":
            def body(x, blk):
                h = L.rms_norm(x, blk["norm"], cfg.norm_eps)
                out, st = self._mamba_prefill(h, blk)
                return x + out, st

            x, states = jax.lax.scan(body, x, params["blocks"])
            cache.update(states)

        elif fam == "hybrid":
            nl, period = cfg.num_layers, cfg.attn_every
            n_groups, tail = nl // period, nl % period
            blocks = params["blocks"]
            main = jax.tree.map(lambda a: a[: n_groups * period].reshape(
                (n_groups, period) + a.shape[1:]), blocks)
            rest = jax.tree.map(lambda a: a[n_groups * period:], blocks)
            shared = jax.tree.map(lambda a: a[0], params["shared_attn"])

            def group_body(x, grp):
                x, kp, vp = attn_with_cache(x, shared)
                x = self._mlp(x, shared)

                def layer_body(x, blk):
                    h = L.rms_norm(x, blk["norm"], cfg.norm_eps)
                    out, st = self._mamba_prefill(h, blk)
                    return x + out, st

                x, states = jax.lax.scan(layer_body, x, grp)
                return x, (states, kp, vp)

            x, (main_states, kc, vc) = jax.lax.scan(group_body, x, main)
            main_states = jax.tree.map(
                lambda a: a.reshape((n_groups * period,) + a.shape[2:]), main_states)
            if tail:
                def layer_body(x, blk):
                    h = L.rms_norm(x, blk["norm"], cfg.norm_eps)
                    out, st = self._mamba_prefill(h, blk)
                    return x + out, st

                x, tail_states = jax.lax.scan(layer_body, x, rest)
                main_states = jax.tree.map(
                    lambda m, t: jnp.concatenate([m, t], 0), main_states, tail_states)
            cache.update(main_states)
            cache["shared"] = {"k": kc, "v": vc}

        elif fam == "vlm":
            img = batch["image_embeds"].astype(cdt)
            n_cross = cfg.num_layers // (cfg.cross_attn_every + 1)
            per = cfg.cross_attn_every
            grouped = jax.tree.map(
                lambda a: a.reshape((n_cross, per) + a.shape[1:]), params["blocks"])

            def group_body(x, scanned):
                grp, cblk = scanned

                def layer_body(x, blk):
                    x, kp, vp = attn_with_cache(x, blk)
                    x = self._mlp(x, blk)
                    return x, (kp, vp)

                x, (kcs, vcs) = jax.lax.scan(layer_body, x, grp)
                ni = img.shape[1]
                ik = jnp.einsum("bnd,dq->bnq", img, cblk["attn"]["wk"].astype(x.dtype))
                iv = jnp.einsum("bnd,dq->bnq", img, cblk["attn"]["wv"].astype(x.dtype))
                ik = ik.reshape(b, ni, cfg.num_kv_heads, cfg.head_dim).astype(cdt)
                iv = iv.reshape(b, ni, cfg.num_kv_heads, cfg.head_dim).astype(cdt)
                h = L.rms_norm(x, cblk["attn_norm"], cfg.norm_eps)
                hq = jnp.einsum("bsd,dq->bsq", h, cblk["attn"]["wq"].astype(x.dtype))
                hq = hq.reshape(b, s, cfg.num_heads, cfg.head_dim)
                if cfg.qk_norm:
                    hq = L.rms_norm(hq, cblk["attn"]["q_norm"], cfg.norm_eps)
                out = L.flash_attention(hq, ik, iv, causal=False)
                out = out.reshape(b, s, cfg.attn_dim)
                out = jnp.einsum("bsq,qd->bsd", out, cblk["attn"]["wo"].astype(x.dtype))
                x = x + jnp.tanh(cblk["gate"]).astype(x.dtype) * out
                h2 = L.swiglu(L.rms_norm(x, cblk["mlp_norm"], cfg.norm_eps),
                              cblk["mlp"]["w_gate"], cblk["mlp"]["w_up"],
                              cblk["mlp"]["w_down"])
                x = x + jnp.tanh(cblk["gate"]).astype(x.dtype) * h2
                return x, (kcs, vcs, ik, iv)

            x, (kc, vc, ik, iv) = jax.lax.scan(
                group_body, x, (grouped, params["cross_blocks"]))
            cache["k"] = kc.reshape(cache["k"].shape)
            cache["v"] = vc.reshape(cache["v"].shape)
            cache["img_k"], cache["img_v"] = ik, iv
        else:
            raise ValueError(fam)

        if last_only:
            x = x[:, -1:, :]
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdt))
        logits = constrain(logits, ("batch", None, "tp"))
        return logits, cache

    def _mamba_prefill(self, h, blk):
        """Mamba block over the full sequence, returning the decode cache."""
        cfg = self.cfg
        cdt = _dtype(cfg.dtype)
        out, st = ssm_lib.mamba2_block(
            h, blk["mamba"], d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
            chunk=cfg.ssm_chunk, norm_eps=cfg.norm_eps)
        st = jax.tree.map(lambda a: a.astype(cdt), st)
        return out, st
