"""Shared neural layers: RMSNorm, RoPE, GQA attention (train + decode paths),
SwiGLU MLP.

Attention has two lowering modes:

* :func:`flash_attention` — blockwise online-softmax (FlashAttention-style
  recomputation structure expressed in pure JAX ``lax.scan``), used for
  training and prefill.  Memory per step is O(q_chunk x kv_chunk); the
  full (S, S) score matrix is never materialised, which is what makes the
  32k-prefill shapes lowerable.  The baseline schedule computes the full
  rectangle with causal masking; ``triangle_schedule=True`` switches to a
  lower-triangle-only block schedule (a §Perf hillclimb lever that halves
  attention FLOPs at long S).
* :func:`decode_attention` — one-token attention against a (possibly
  sequence-sharded) KV cache.  With the cache's sequence dim sharded over the
  ``data`` mesh axis, XLA turns the softmax reductions into the flash-style
  two-pass all-reduce — this is what makes batch=1 x 524k decode shardable.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import attn_partition, constrain


# ---------------------------------------------------------------------------
# Norms / embeddings / MLP
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, w_down.astype(x.dtype))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) causal attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _blk_scores(q_blk, k_blk, scale, causal, qi, kj, q_chunk, kv_chunk):
    """(B, Cq, KV, G, D) x (B, Ck, KV, D) -> f32 (B, KV, G, Cq, Ck) scores."""
    s = jnp.einsum("bqkgd,bckd->bkgqc", q_blk, k_blk).astype(jnp.float32) * scale
    if causal:
        q_pos = qi * q_chunk + jnp.arange(q_chunk)
        k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
        mask = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
    return s


def _flash_fwd(q, k, v, causal, q_chunk, kv_chunk, triangle):
    """Returns (out (B,Sq,H,D), lse (B,KV,G,Sq))."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = d ** -0.5
    qc = q.reshape(b, nq, q_chunk, kv, g, d).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(b, nk, kv_chunk, kv, d)
    vc = v.reshape(b, nk, kv_chunk, kv, d)

    def q_step(_, q_in):
        qi, q_blk = q_in

        def kv_step(acc, kj):
            o, m, l = acc
            k_blk = jax.lax.dynamic_index_in_dim(kc, kj, 1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vc, kj, 1, keepdims=False)
            s = _blk_scores(q_blk, k_blk, scale, causal, qi, kj, q_chunk, kv_chunk)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(v_blk.dtype), v_blk)
            o_new = o * alpha[..., None] + pv.astype(jnp.float32)
            new_acc = (o_new, m_new, l_new)
            if triangle and causal:
                # Skip strictly-upper blocks (they contribute nothing).
                take = kj * kv_chunk <= (qi * q_chunk + q_chunk - 1)
                new_acc = jax.tree.map(
                    lambda n, o_: jnp.where(take, n, o_), new_acc, acc)
            return new_acc, None

        o0 = jnp.zeros((b, kv, g, q_chunk, d), jnp.float32)
        m0 = jnp.full((b, kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
        (o, m, l), _ = jax.lax.scan(kv_step, (o0, m0, l0), jnp.arange(nk))
        out_blk = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        lse_blk = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out_blk, lse_blk)

    _, (outs, lses) = jax.lax.scan(q_step, None, (jnp.arange(nq), qc))
    # outs: (nq, B, KV, G, Cq, D) -> (B, Sq, H, D); lse -> (B, KV, G, Sq)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, d)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, kv, g, sq)
    return out, lse


def _flash_bwd_impl(q, k, v, out, lse, do, causal, q_chunk, kv_chunk, triangle):
    """Blockwise FlashAttention backward (recompute p from lse)."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = d ** -0.5
    qc = q.reshape(b, nq, q_chunk, kv, g, d).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(b, nk, kv_chunk, kv, d)
    vc = v.reshape(b, nk, kv_chunk, kv, d)
    doc = do.reshape(b, nq, q_chunk, kv, g, d).transpose(1, 0, 2, 3, 4, 5)
    lsec = lse.reshape(b, kv, g, nq, q_chunk).transpose(3, 0, 1, 2, 4)
    # D_i = rowsum(do * out): (nq, B, KV, G, Cq)
    dsum = jnp.sum((do * out).astype(jnp.float32), axis=-1)
    dsumc = dsum.reshape(b, nq, q_chunk, kv, g).transpose(1, 0, 3, 4, 2)

    def q_step(carry, q_in):
        dk_acc, dv_acc = carry            # (B, Sk, KV, D) f32
        qi, q_blk, do_blk, lse_blk, d_blk = q_in

        def kv_step(c2, kj):
            dq_i, dk_acc, dv_acc = c2
            k_blk = jax.lax.dynamic_index_in_dim(kc, kj, 1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vc, kj, 1, keepdims=False)
            s = _blk_scores(q_blk, k_blk, scale, causal, qi, kj, q_chunk, kv_chunk)
            p = jnp.exp(s - lse_blk[..., None])                       # (B,KV,G,Cq,Ck)
            dv_c = jnp.einsum("bkgqc,bqkgd->bckd", p.astype(do_blk.dtype), do_blk)
            dp = jnp.einsum("bqkgd,bckd->bkgqc", do_blk, v_blk).astype(jnp.float32)
            ds = p * (dp - d_blk[..., None])                          # f32
            dq_c = jnp.einsum("bkgqc,bckd->bqkgd", ds.astype(k_blk.dtype), k_blk)
            dk_c = jnp.einsum("bkgqc,bqkgd->bckd", ds.astype(q_blk.dtype), q_blk)
            if triangle and causal:
                take = (kj * kv_chunk <= (qi * q_chunk + q_chunk - 1)).astype(jnp.float32)
                dq_c = dq_c * take
                dk_c = dk_c * take
                dv_c = dv_c * take
            dq_i = dq_i + dq_c.astype(jnp.float32) * scale
            off = kj * kv_chunk
            upd = lambda acc, c: jax.lax.dynamic_update_slice_in_dim(
                acc, jax.lax.dynamic_slice_in_dim(acc, off, kv_chunk, 1)
                + c.astype(jnp.float32), off, 1)
            dk_acc = upd(dk_acc, dk_c * scale)
            dv_acc = upd(dv_acc, dv_c)
            return (dq_i, dk_acc, dv_acc), None

        dq0 = jnp.zeros((b, q_chunk, kv, g, d), jnp.float32)
        (dq_i, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_i

    dk0 = jnp.zeros((b, sk, kv, d), jnp.float32)
    dv0 = jnp.zeros((b, sk, kv, d), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(
        q_step, (dk0, dv0), (jnp.arange(nq), qc, doc, lsec, dsumc))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, d).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, q_chunk, kv_chunk, triangle):
    out, _ = _flash_fwd(q, k, v, causal, q_chunk, kv_chunk, triangle)
    return out


def _flash_fwd_rule(q, k, v, causal, q_chunk, kv_chunk, triangle):
    out, lse = _flash_fwd(q, k, v, causal, q_chunk, kv_chunk, triangle)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, q_chunk, kv_chunk, triangle, res, do):
    q, k, v, out, lse = res
    return _flash_bwd_impl(q, k, v, out, lse, do, causal, q_chunk, kv_chunk, triangle)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    triangle_schedule: bool = False,
) -> jnp.ndarray:
    """Blockwise attention with a FlashAttention-style custom VJP.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D); GQA via H % KV == 0.  The (S, S)
    score matrix is never materialised in either pass: the forward saves only
    (q, k, v, out, logsumexp) and the backward recomputes probabilities per
    (q-block, kv-block) pair.  This is what keeps 32k-prefill and 4k-train
    residency O(S·d) instead of O(S²).
    """
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    assert h % kv == 0, (h, kv)
    q_chunk = min(q_chunk, sq, max(sq // 16, 64))  # >=16 chunks: context-
    kv_chunk = min(kv_chunk, sk)                   # parallel shard alignment
    while sq % q_chunk:      # non-power-of-two sequence (e.g. image tokens)
        q_chunk //= 2
    while sk % kv_chunk:
        kv_chunk //= 2
    assert q_chunk >= 1 and kv_chunk >= 1
    return _flash(q, k, v, causal, q_chunk, kv_chunk, triangle_schedule)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    cur_len: jnp.ndarray,
) -> jnp.ndarray:
    """One-token attention. q: (B, 1, H, D); caches: (B, S, KV, D).

    Works with the cache sequence dim sharded over the data axis: the max/sum
    reductions over S lower to all-reduces, giving sequence-parallel decode.
    """
    b, _, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    g = h // kv
    scale = d ** -0.5
    qh = q.reshape(b, kv, g, d)
    scores = jnp.einsum("bkgd,bskd->bkgs", qh, k_cache).astype(jnp.float32) * scale
    mask = jnp.arange(s)[None, :] < cur_len[:, None]          # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", (p / l).astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, d)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + qk-norm)
# ---------------------------------------------------------------------------

def attention_block(
    x: jnp.ndarray,
    params: dict,
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    qk_norm: bool,
    norm_eps: float,
    positions: Optional[jnp.ndarray] = None,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    triangle_schedule: bool = False,
) -> jnp.ndarray:
    """Self-attention (or cross-attention when ``kv_override`` is given).

    params: wq (D, H*hd), wk (D, KV*hd), wv (D, KV*hd), wo (H*hd, D)
            [+ q_norm (hd,), k_norm (hd,) when qk_norm].
    """
    b, s, _ = x.shape
    h, kvh, hd = num_heads, num_kv_heads, head_dim
    q = jnp.einsum("bsd,dq->bsq", x, params["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    if kv_override is None:
        k = jnp.einsum("bsd,dq->bsq", x, params["wk"].astype(x.dtype)).reshape(b, s, kvh, hd)
        v = jnp.einsum("bsd,dq->bsq", x, params["wv"].astype(x.dtype)).reshape(b, s, kvh, hd)
        causal = True
    else:
        k, v = kv_override
        causal = False
    if qk_norm:
        q = rms_norm(q, params["q_norm"], norm_eps)
        k = rms_norm(k, params["k_norm"], norm_eps)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if kv_override is None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    q, k, v = attn_partition(q, k, v, num_heads, num_kv_heads)
    out = flash_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                          kv_chunk=kv_chunk, triangle_schedule=triangle_schedule)
    out = out.reshape(b, s, h * hd)
    return jnp.einsum("bsq,qd->bsd", out, params["wo"].astype(x.dtype))
