"""Top-k routed Mixture-of-Experts with capacity-based dispatch (GShard/Switch
style), expert-parallel over the ``model`` mesh axis.

Design notes for the 1000+-chip regime:

* Tokens are processed in **groups** (one group = one ``group_size`` slice of
  a sequence).  The dispatch/combine one-hots are (G, t, E, C) — their size
  scales with ``group_size * k^2 * capacity_factor`` per token and is
  independent of E, keeping the dispatch overhead ~2% of expert FLOPs even at
  E=128 (arctic).
* All shapes are static: over-capacity tokens are dropped (standard training
  behaviour), counted in the aux metrics.
* Sharding: groups over the batch axes, experts over ``model``.  The
  dispatch einsum then lowers to an all-to-all over the model axis, the
  expert matmuls stay local, and the combine einsum all-to-alls back.
* Router runs in fp32 (numerics), with the usual load-balance auxiliary loss
  and router z-loss.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


def moe_block(
    x: jnp.ndarray,
    params: dict,
    *,
    num_experts: int,
    k: int,
    capacity_factor: float = 1.25,
    group_size: int = 1024,
) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, D) -> (out (B, S, D), aux metrics).

    params: router (D, E); w_gate/w_up (E, D, F); w_down (E, F, D).
    """
    b, s, d = x.shape
    e = num_experts
    gs = min(group_size, s)
    assert s % gs == 0, (s, gs)
    xg = constrain(x.reshape(b * (s // gs), gs, d), ("batch", None, None))
    g_dim, t = xg.shape[0], gs

    router_logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)          # (G, t, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)         # (G, t, k)
    # Renormalise the kept gates (top-k of softmax).
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    capacity = max(int(capacity_factor * t * k / e), 4)

    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (G, t, k, E)
    flat = onehot.reshape(g_dim, t * k, e)
    # Slot position of each (token, choice) within its expert's capacity.
    pos = jnp.cumsum(flat, axis=1) - flat                   # (G, t*k, E)
    keep = flat * (pos < capacity)
    slot_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity, dtype=jnp.float32)
    dispatch = (keep[..., None] * slot_oh).reshape(g_dim, t, k, e, capacity)
    dispatch = jnp.sum(dispatch, axis=2)                    # (G, t, E, C)
    combine = dispatch * jnp.einsum(
        "gtk,gtke->gte", gate_vals, onehot * (keep.reshape(g_dim, t, k, e)))[..., None]

    # ---- dispatch -> expert matmuls -> combine ----
    compute_dtype = x.dtype
    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch.astype(compute_dtype), xg)
    expert_in = constrain(expert_in, ("batch", "tp", None, None))
    h_gate = jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"].astype(compute_dtype))
    h_up = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"].astype(compute_dtype))
    h = jax.nn.silu(h_gate) * h_up
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(compute_dtype))
    expert_out = constrain(expert_out, ("batch", "tp", None, None))
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(compute_dtype), expert_out)

    # ---- aux losses (fp32) ----
    # Load-balance: fraction of tokens routed to e * mean router prob for e.
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))     # (E,) token fraction * k
    aux_loss = e * jnp.sum(me * ce) / k
    z_loss = jnp.mean(jax.nn.logsumexp(router_logits, axis=-1) ** 2)
    dropped = 1.0 - jnp.sum(keep) / (g_dim * t * k)

    aux = {"moe_aux_loss": aux_loss, "moe_z_loss": z_loss, "moe_dropped": dropped}
    return out.reshape(b, s, d), aux
