"""LM model zoo: composable decoder architectures for all 10 assigned configs."""

from repro.models.config import ModelConfig, reduced
from repro.models.model import Model
from repro.models.decode import DecodeEngine
