"""Mamba2 / SSD (state-space duality) blocks — training scan + O(1) decode.

Implements the chunked SSD algorithm of arXiv:2405.21060 (ngroups=1):
within a chunk the recurrence is evaluated as a masked quadratic form
(MXU-friendly); across chunks a small recurrence propagates the (H, P, N)
states.  Decode is the exact single-step recurrence against a carried
(conv_state, ssm_state) cache — this is what makes the ``long_500k`` shape
O(1) per token for the SSM/hybrid architectures.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import rms_norm


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., L) log-decays -> (..., L, L) with [i, j] = sum_{k=j+1..i} a_k
    for i >= j, -inf above the diagonal."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    idx = jnp.arange(l)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(
    x: jnp.ndarray,       # (B, S, H, P) — inputs, *already* scaled by dt
    a: jnp.ndarray,       # (B, S, H)    — log decay per step (dt * A, <= 0)
    bmat: jnp.ndarray,    # (B, S, N)
    cmat: jnp.ndarray,    # (B, S, N)
    chunk: int,
    initial_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD. Returns (y (B, S, H, P), final_state (B, H, P, N))."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xc = x.reshape(b, nc, chunk, h, p)
    ac = a.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)    # (B, H, nc, L)
    bc = bmat.reshape(b, nc, chunk, n)
    cc = cmat.reshape(b, nc, chunk, n)

    a_cs = jnp.cumsum(ac, axis=-1)                            # (B, H, nc, L)
    ldec = jnp.exp(_segsum(ac))                               # (B, H, nc, L, L)

    # 1) intra-chunk (quadratic, MXU-heavy)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        cc, bc, ldec.astype(cc.dtype), xc)

    # 2) per-chunk output states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)             # (B, H, nc, L)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn",
                        bc, decay_states.astype(bc.dtype), xc)

    # 3) inter-chunk recurrence over the nc chunk states (sequential scan —
    #    O(nc) with tiny state; avoids the (nc+1)^2 decay matrix so the same
    #    code path serves 4k training and 512k prefill).
    chunk_decay = jnp.exp(a_cs[..., -1])                      # (B, H, nc)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), x.dtype)

    def step(carry, inp):
        st, dec = inp                                         # (B,H,P,N), (B,H)
        carry_new = carry * dec[..., None, None].astype(carry.dtype) + st
        return carry_new, carry                               # emit state *entering* chunk

    (final_state, prev_states) = jax.lax.scan(
        step,
        initial_state,
        (states.transpose(1, 0, 2, 3, 4),                     # (nc, B, H, P, N)
         chunk_decay.transpose(2, 0, 1)),                     # (nc, B, H)
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (B, nc, H, P, N)

    # 4) inter-chunk contribution to outputs
    state_decay_out = jnp.exp(a_cs)                           # (B, H, nc, L)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp",
                       cc, prev_states, state_decay_out.astype(cc.dtype))

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------

def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d. x: (B, S, C); w: (K, C).

    With ``state`` ((B, K-1, C), the trailing inputs of the previous step) the
    function also returns the new state — used by decode.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                    # (B, S+K-1, C)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
              for i in range(k))
    new_state = xp[:, -(k - 1):, :]
    return out, new_state


def mamba2_block(
    x: jnp.ndarray,
    params: dict,
    *,
    d_state: int,
    head_dim: int,
    chunk: int,
    norm_eps: float,
    cache: Optional[dict] = None,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B, S, D) -> (B, S, D). ``cache`` enables single-step decode.

    params (projections kept separate so tensor-parallel shard boundaries
    align with component boundaries — heads/Din shard over ``model``, the
    shared B/C streams stay replicated):
      w_z, w_x (D, Din); w_b, w_c (D, N); w_dt (D, H);
      conv_x (K, Din); conv_b, conv_c (K, N);
      a_log, dt_bias, d_skip (H,); norm (Din,); w_out (Din, D).
    """
    b, s, d = x.shape
    d_in = params["w_out"].shape[0]
    h = d_in // head_dim
    n = d_state

    z = jnp.einsum("bsd,de->bse", x, params["w_z"].astype(x.dtype))
    xs_pre = jnp.einsum("bsd,de->bse", x, params["w_x"].astype(x.dtype))
    b_pre = jnp.einsum("bsd,dn->bsn", x, params["w_b"].astype(x.dtype))
    c_pre = jnp.einsum("bsd,dn->bsn", x, params["w_c"].astype(x.dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["w_dt"].astype(x.dtype))

    # Depthwise causal conv commutes with the channel split, so each stream
    # carries its own small conv (and its own decode state).
    cx = cache["conv_x"] if cache is not None else None
    cb = cache["conv_b"] if cache is not None else None
    ccs = cache["conv_c"] if cache is not None else None
    xs, new_cx = _causal_conv(xs_pre, params["conv_x"], cx)
    bmat, new_cb = _causal_conv(b_pre, params["conv_b"], cb)
    cmat, new_cc = _causal_conv(c_pre, params["conv_c"], ccs)
    xs = constrain(jax.nn.silu(xs), ("batch", None, "tp"))
    bmat = jax.nn.silu(bmat)
    cmat = jax.nn.silu(cmat)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))          # (H,), negative
    log_decay = dt * a[None, None, :]                          # (B, S, H)

    xh = xs.reshape(b, s, h, head_dim)
    x_scaled = constrain(xh * dt[..., None].astype(xh.dtype),
                         ("batch", None, "tp", None))

    if cache is None:
        y, final_state = ssd_scan(x_scaled, log_decay, bmat, cmat, chunk)
        # Decode-ready states: trailing (K-1) pre-activation conv inputs +
        # the final SSM state.  Returned so prefill fills the cache in one
        # pass (no recomputation).
        k_w = params["conv_x"].shape[0]
        new_cache = {
            "conv_x": xs_pre[:, -(k_w - 1):, :],
            "conv_b": b_pre[:, -(k_w - 1):, :],
            "conv_c": c_pre[:, -(k_w - 1):, :],
            "ssm": final_state,
        }
    else:
        # O(1) decode recurrence: state' = exp(dt*a)*state + dt*B (x ⊗)
        st = cache["ssm"]                                     # (B, H, P, N)
        dec = jnp.exp(log_decay[:, 0, :])                     # (B, H)
        upd = jnp.einsum("bhp,bn->bhpn", x_scaled[:, 0], bmat[:, 0])
        st = st * dec[..., None, None].astype(st.dtype) + upd
        y = jnp.einsum("bhpn,bn->bhp", st, cmat[:, 0])[:, None]  # (B, 1, H, P)
        final_state = st
        new_cache = {"conv_x": new_cx, "conv_b": new_cb, "conv_c": new_cc,
                     "ssm": final_state}

    y = y + xh * params["d_skip"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = rms_norm(y, params["norm"], norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))
    return out, new_cache
