"""Model configuration dataclass shared by all 10 assigned architectures.

One frozen config fully determines parameter shapes, sharding specs and the
block schedule.  Families:

* ``dense``  — pre-norm decoder (GQA + SwiGLU), optional qk-norm.
* ``moe``    — dense attention + top-k routed experts (optional dense residual).
* ``ssm``    — Mamba2 / SSD blocks, attention-free.
* ``hybrid`` — Mamba2 backbone + a weight-shared attention block applied every
  ``attn_every`` layers (Zamba2-style).
* ``vlm``    — dense decoder with interleaved cross-attention layers over
  precomputed image-patch embeddings (frontend stubbed per assignment).
* ``audio``  — dense decoder over precomputed EnCodec frame embeddings
  (frontend stubbed); logits over the codec vocab.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    num_heads: int = 0                # 0 => attention-free
    num_kv_heads: int = 0
    head_dim: int = 0

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False      # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (Zamba2) ---
    attn_every: int = 0               # apply the shared attn block every k layers

    # --- VLM ---
    cross_attn_every: int = 0         # insert a cross-attn layer after every k
    num_image_tokens: int = 0
    # --- audio ---
    frame_inputs: bool = False        # inputs are precomputed frame embeddings

    # --- misc ---
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"           # compute dtype
    param_dtype: str = "float32"
    remat: bool = True                # activation checkpointing per layer

    # ---- derived ----
    @property
    def attn_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.num_heads > 0 or self.family == "hybrid"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM/hybrid only)."""
        return self.family in ("ssm", "hybrid")

    def validate(self) -> "ModelConfig":
        if self.family in ("dense", "moe", "vlm", "audio"):
            assert self.num_heads > 0 and self.head_dim > 0
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, "GQA group size"
        if self.family == "moe":
            assert self.num_experts > 0 and self.experts_per_token > 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0
            assert self.ssm_inner % self.ssm_head_dim == 0
        if self.family == "hybrid":
            assert self.attn_every > 0 and self.num_heads > 0
        if self.family == "vlm":
            assert self.cross_attn_every > 0 and self.num_image_tokens > 0
        return self


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    if cfg.family == "vlm":
        # self-layer count must equal n_cross * cross_attn_every
        n_layers = 2 * (min(cfg.cross_attn_every, 2) + 1)
    elif cfg.family == "hybrid":
        # exercise both the grouped scan and the tail layers
        n_layers = 2 * min(cfg.attn_every, 2) + 1
    else:
        n_layers = min(cfg.num_layers, 2)
    base = dict(
        name=cfg.name + "-smoke",
        family=cfg.family,
        num_layers=n_layers,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=16 if cfg.num_heads else 0,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        dense_residual=cfg.dense_residual,
        ssm_state=min(cfg.ssm_state, 16),
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        attn_every=min(cfg.attn_every, 2),
        cross_attn_every=min(cfg.cross_attn_every, 2),
        num_image_tokens=16 if cfg.num_image_tokens else 0,
        frame_inputs=cfg.frame_inputs,
        qk_norm=cfg.qk_norm,
        tie_embeddings=cfg.tie_embeddings,
        remat=False,
        # CPU smoke settings: f32 compute keeps decode/forward parity tight;
        # a large capacity factor disables MoE token dropping so the routed
        # path is sequence-split invariant (capacity depends on group size).
        dtype="float32",
        capacity_factor=8.0,
    )
    base.update(overrides)
    return ModelConfig(**base).validate()
