"""Appendable corpus store: sealed base + delta segments + LSM compaction
over the prepared-collection engine."""

from repro.store.store import (
    FUNNEL_SUM_FIELDS,
    PROBE_SUM_FIELDS,
    CompactionPolicy,
    CorpusStore,
    Segment,
    StoreStats,
    empty_collection,
    merge_pairs,
    sum_stats,
)

__all__ = [
    "FUNNEL_SUM_FIELDS",
    "PROBE_SUM_FIELDS",
    "CompactionPolicy",
    "CorpusStore",
    "Segment",
    "StoreStats",
    "empty_collection",
    "merge_pairs",
    "sum_stats",
]
