"""The appendable corpus store: delta segments + LSM-style compaction.

Every cached artifact on a :class:`~repro.core.engine.PreparedCollection`
(length sort, packed bitmap words, CSR postings, sharded slabs) is
invalidated by any change to its source collection — so the prepared-corpus
serving story could only serve a *frozen* corpus.  Real dedup-at-scale
appends continuously.  This module applies the LSM discipline (the same
reason the candidate-free MapReduce R-S join of arXiv:2506.03893 builds
filter-and-verification trees: never re-index a side per batch) to the
engine's build-once artifacts:

* A :class:`CorpusStore` holds one **sealed base segment** — a full
  ``PreparedCollection`` with all its cached artifacts — plus an ordered
  list of small **delta segments** (each its own ``PreparedCollection``).
* :meth:`CorpusStore.append` prepares *only* the new delta.  The base is
  untouched — provable, not just hoped: the base segment's ``builds``
  counters never move on append.
* Every probe / self-join runs the **base join ∪ per-delta joins** (the
  ``dedup_against`` decomposition): a probe batch joins against every
  segment independently; a store self-join is each segment's self-join
  plus every earlier-segment × later-segment R×S join.  Pairs come back in
  **store-global ids** (append order: base rows first, then each delta),
  and the funnel :class:`~repro.core.join.JoinStats` are summed across
  segment joins.
* A :class:`CompactionPolicy` (delta-count or size-ratio triggered, plus an
  explicit :meth:`CorpusStore.compact`) folds the deltas into a new sealed
  base — artifacts are rebuilt **once per merge** instead of once per
  append.  Global ids are append-ordered, so compaction preserves them.

Exactness contract (enforced by ``tests/test_store.py`` and the store sweep
in ``tests/test_driver_conformance.py``): at *every* compaction state,

* the store's pair set is **bit-identical** to joining a from-scratch
  rebuild of the materialized collection with the same plan, and
* the summed funnel counters (``total_pairs`` / ``candidates`` /
  ``verified_true`` / ``candidates_generated``, plus ``postings_expanded``
  for probes) equal the from-scratch join's exactly for the device drivers
  — those fields count per-pair predicates, so they are invariant under
  partitioning the grid by segments.  (``blocks_total`` /
  ``blocks_skipped`` / ``overflow_blocks`` describe the *decomposition*
  and are summed but not contract-bound; a self-join's
  ``postings_expanded`` is direction-dependent and likewise exempt.)

Every driver registered in :data:`repro.core.plan.DRIVERS` must declare its
store behavior in :data:`repro.core.plan.STORE_SUPPORT` (``"exact"`` =
pairs + funnel sums, ``"pairs"`` = pairs only) — the conformance suite
fails collection if a new driver ships without a declaration.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.collection import Collection
from repro.core.constants import JACCARD, PAD_TOKEN
from repro.core.engine import JoinEngine, PreparedCollection, prepare
from repro.core.join import JoinStats
from repro.core.plan import JoinPlan, JoinPlanner

#: JoinStats fields that are per-pair predicates — invariant under the
#: segment decomposition, so their sums are contract-bound vs a
#: from-scratch rebuild.  ``postings_expanded`` joins this set for probes
#: (probe side fixed on both sides of the comparison) but not for
#: self-joins (a full self-join expands both directions of a symmetric
#: window; the segmented cross joins expand one).
FUNNEL_SUM_FIELDS = ("total_pairs", "candidates", "verified_true",
                     "candidates_generated")
PROBE_SUM_FIELDS = FUNNEL_SUM_FIELDS + ("postings_expanded",)


def sum_stats(stats_list: Sequence[JoinStats]) -> JoinStats:
    """Field-wise sum of :class:`~repro.core.join.JoinStats` counters."""
    out = JoinStats()
    for s in stats_list:
        for f in dataclasses.fields(JoinStats):
            setattr(out, f.name, getattr(out, f.name) + getattr(s, f.name))
    return out


def merge_pairs(chunks: Sequence[np.ndarray]) -> np.ndarray:
    """Concatenate per-segment pair buffers and lexsort into the canonical
    (col0-major) order every driver emits."""
    chunks = [c for c in chunks if len(c)]
    if not chunks:
        return np.zeros((0, 2), dtype=np.int64)
    p = np.concatenate(chunks, axis=0).astype(np.int64)
    return p[np.lexsort((p[:, 1], p[:, 0]))]


def empty_collection(max_len: int = 1) -> Collection:
    """A zero-row collection (the base of a store born empty)."""
    return Collection(tokens=np.full((0, max(max_len, 1)), PAD_TOKEN,
                                     dtype=np.int32),
                      lengths=np.zeros((0,), dtype=np.int32))


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """When to fold the delta list into a new sealed base.

    ``max_deltas`` triggers on delta *count* (every delta adds one more
    segment join per probe); ``size_ratio`` triggers when the delta rows
    exceed that fraction of the base (LSM size-ratio discipline — the point
    where one merge amortizes better than many small segment joins).
    """

    max_deltas: int = 4
    size_ratio: float = 0.5

    def __post_init__(self):
        if self.max_deltas < 1:
            raise ValueError(f"max_deltas must be >= 1, got {self.max_deltas}")
        if self.size_ratio <= 0:
            raise ValueError(f"size_ratio must be > 0, got {self.size_ratio}")

    def should_compact(self, base_rows: int,
                       delta_rows: Sequence[int]) -> bool:
        if not delta_rows:
            return False
        if len(delta_rows) >= self.max_deltas:
            return True
        return sum(delta_rows) > self.size_ratio * max(base_rows, 1)

    @classmethod
    def never(cls) -> "CompactionPolicy":
        """Auto-compaction disabled; only explicit ``compact()`` merges."""
        return cls(max_deltas=1 << 30, size_ratio=float("inf"))


@dataclasses.dataclass
class StoreStats:
    """The store's observability rollup."""

    segments: int            # 1 (base) + live delta count
    base_rows: int
    delta_rows: int
    delta_count: int
    delta_fraction: float    # delta_rows / max(total rows, 1)
    appends: int
    compactions: int
    probes: int
    builds: Dict[str, int]           # the LIVE base segment's build counters
    delta_builds: Dict[str, int]     # summed over live delta segments
    lifetime_builds: Dict[str, int]  # base + deltas + retired segments

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class Segment:
    """One sealed store segment: a prepared collection at a global-id
    offset.  ``engine`` is the segment's lazily-built
    :class:`~repro.core.engine.JoinEngine` (shared plan, cached so repeat
    probes reuse every segment-side artifact)."""

    __slots__ = ("prepared", "offset", "kind", "_engine")

    def __init__(self, prepared: PreparedCollection, offset: int, kind: str):
        self.prepared = prepared
        self.offset = int(offset)
        self.kind = kind
        self._engine: Optional[JoinEngine] = None

    @property
    def rows(self) -> int:
        return self.prepared.num_sets

    def engine(self, store: "CorpusStore") -> JoinEngine:
        if self._engine is None:
            self._engine = JoinEngine(
                self.prepared, store.sim, store.tau, plan=store.plan,
                mesh=store.mesh, axis=store.axis)
        return self._engine

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Segment({self.kind}, offset={self.offset}, "
                f"rows={self.rows})")


class CorpusStore:
    """An appendable corpus over the prepared-collection engine.

    ``CorpusStore(base, sim, tau)`` seals ``base`` as the store's first
    segment and resolves one :class:`~repro.core.plan.JoinPlan` shared by
    every segment join for the store's lifetime (pass ``plan=`` to pin it
    — the exactness tests compare against a from-scratch rebuild under the
    *same* plan).  ``append`` adds a delta segment (preparing only the
    delta), ``probe``/``self_join`` run the segment-union join, and
    ``compact`` seals everything into a fresh base.

    Documents are addressed by **store-global ids**: the base's original
    indices first, then each delta's, in append order.  Compaction
    materializes segments in exactly that order, so global ids survive any
    number of merges.
    """

    def __init__(self, base: Collection | PreparedCollection | None = None,
                 sim: str = JACCARD, tau: float = 0.8, *,
                 plan: Optional[JoinPlan] = None,
                 planner: Optional[JoinPlanner] = None,
                 policy: Optional[CompactionPolicy] = None,
                 mesh=None, axis=None):
        if base is None:
            base = empty_collection()
        prepared = prepare(base)
        self.sim = sim
        self.tau = float(tau)
        if plan is None:
            planner = planner or JoinPlanner()
            plan = planner.plan(sim, self.tau, n_r=max(prepared.num_sets, 1))
        if plan.sim != sim or plan.tau != self.tau:
            raise ValueError(
                f"plan is for (sim={plan.sim}, tau={plan.tau}); the store "
                f"was asked for (sim={sim}, tau={self.tau})")
        self.plan = plan
        self.policy = policy or CompactionPolicy()
        self.mesh = mesh
        self.axis = axis
        self.base = Segment(prepared, 0, "base")
        self.deltas: List[Segment] = []
        self.appends = 0
        self.compactions = 0
        self.probes = 0
        #: bumped on every mutation (append or compact)
        self.version = 0
        #: bumped only when the base segment is replaced (compaction) — a
        #: resident consumer (``serve.JoinSession``) rebinds its on-device
        #: base artifacts iff this moved.
        self.base_version = 0
        self._retired_builds: collections.Counter = collections.Counter()

    # -- shape ---------------------------------------------------------------

    def segments(self) -> List[Segment]:
        return [self.base] + list(self.deltas)

    @property
    def num_sets(self) -> int:
        return self.base.rows + sum(d.rows for d in self.deltas)

    def __len__(self) -> int:
        return self.num_sets

    @property
    def max_len(self) -> int:
        return max((s.prepared.source.tokens.shape[1]
                    for s in self.segments()), default=1)

    # -- mutation ------------------------------------------------------------

    def append(self, col: Collection | PreparedCollection, *,
               compact: bool | str = "auto") -> Segment:
        """Seal ``col`` as a new delta segment; only the delta is prepared.

        ``compact="auto"`` (default) lets :attr:`policy` decide whether to
        fold afterwards; ``True`` forces a merge, ``False`` suppresses it.
        Returns the new segment (its ``offset`` is the first global id the
        appended documents received — valid across future compactions).
        """
        seg = Segment(prepare(col), self.num_sets, "delta")
        self.deltas.append(seg)
        self.appends += 1
        self.version += 1
        if compact is True or (
                compact == "auto" and self.policy.should_compact(
                    self.base.rows, [d.rows for d in self.deltas])):
            self.compact()
        return seg

    def compact(self) -> bool:
        """Fold every delta into a new sealed base (one artifact rebuild
        per merge instead of one per append).  No-op without deltas.
        Returns whether a merge happened."""
        if not self.deltas:
            return False
        for seg in self.segments():
            self._retired_builds.update(seg.prepared.builds)
        merged = self.collection()
        self.base = Segment(prepare(merged), 0, "base")
        self.deltas = []
        self.compactions += 1
        self.version += 1
        self.base_version += 1
        return True

    def collection(self) -> Collection:
        """The materialized union in global-id order (also the compaction
        input and the from-scratch oracle's input in the exactness tests)."""
        segs = self.segments()
        width = self.max_len
        n = self.num_sets
        tokens = np.full((n, width), PAD_TOKEN, dtype=np.int32)
        lengths = np.zeros((n,), dtype=np.int32)
        for seg in segs:
            src = seg.prepared.source
            o, k = seg.offset, seg.rows
            if k:
                tokens[o:o + k, :src.tokens.shape[1]] = src.tokens
                lengths[o:o + k] = src.lengths
        return Collection(tokens=tokens, lengths=lengths)

    # -- joins ---------------------------------------------------------------

    def probe(self, batch: Collection | PreparedCollection, *,
              return_stats: bool = True):
        """Join one batch against every segment; pairs come back as
        ``(store_global_id, batch_index)`` in the canonical lexsorted order
        with the funnel counters summed across segment joins."""
        self.probes += 1
        if batch.num_sets == 0:
            out = merge_pairs([]), JoinStats()
            return out if return_stats else out[0]
        prep_b = prepare(batch)
        chunks: List[np.ndarray] = []
        stats: List[JoinStats] = []
        for seg in self.segments():
            if seg.rows == 0:
                continue
            p, st = seg.engine(self).probe(prep_b)
            if len(p):
                chunks.append(p + np.array([seg.offset, 0], dtype=np.int64))
            stats.append(st)
        pairs, total = merge_pairs(chunks), sum_stats(stats)
        return (pairs, total) if return_stats else pairs

    def probe_deltas(self, batch: Collection | PreparedCollection
                     ) -> Tuple[np.ndarray, List[JoinStats]]:
        """The delta part of :meth:`probe` alone — the serving layer fuses
        the base join on device and adds this on top (bit-identical to the
        sequential decomposition because these are the *same* per-delta
        engine probes the sequential path runs)."""
        if batch.num_sets == 0 or not self.deltas:
            return merge_pairs([]), []
        prep_b = prepare(batch)
        chunks: List[np.ndarray] = []
        stats: List[JoinStats] = []
        for seg in self.deltas:
            if seg.rows == 0:
                continue
            p, st = seg.engine(self).probe(prep_b)
            if len(p):
                chunks.append(p + np.array([seg.offset, 0], dtype=np.int64))
            stats.append(st)
        return merge_pairs(chunks), stats

    def self_join(self, *, return_stats: bool = False):
        """The whole store joined against itself: each segment's self-join
        plus every earlier×later segment R×S join (``dedup_against``
        semantics) — global pair ids, summed stats."""
        segs = [s for s in self.segments() if s.rows > 0]
        chunks: List[np.ndarray] = []
        stats: List[JoinStats] = []
        for i, seg in enumerate(segs):
            p, st = seg.engine(self).self_join(return_stats=True)
            if len(p):
                chunks.append(p + seg.offset)
            stats.append(st)
            for later in segs[i + 1:]:
                p, st = seg.engine(self).probe(later.prepared)
                if len(p):
                    chunks.append(p + np.array([seg.offset, later.offset],
                                               dtype=np.int64))
                stats.append(st)
        pairs, total = merge_pairs(chunks), sum_stats(stats)
        return (pairs, total) if return_stats else pairs

    # -- observability -------------------------------------------------------

    def builds(self) -> Dict[str, int]:
        """The live base segment's build counters — ``builds()["sort"]`` /
        ``builds()["bitmap"]`` staying put across appends is the proof that
        ``append`` never rebuilds the base."""
        return dict(self.base.prepared.builds)

    def stats(self) -> StoreStats:
        delta_rows = sum(d.rows for d in self.deltas)
        total = self.base.rows + delta_rows
        delta_builds: collections.Counter = collections.Counter()
        for d in self.deltas:
            delta_builds.update(d.prepared.builds)
        lifetime = collections.Counter(self._retired_builds)
        lifetime.update(self.base.prepared.builds)
        lifetime.update(delta_builds)
        return StoreStats(
            segments=1 + len(self.deltas),
            base_rows=self.base.rows,
            delta_rows=delta_rows,
            delta_count=len(self.deltas),
            delta_fraction=delta_rows / max(total, 1),
            appends=self.appends,
            compactions=self.compactions,
            probes=self.probes,
            builds=self.builds(),
            delta_builds=dict(delta_builds),
            lifetime_builds=dict(lifetime),
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"CorpusStore(n={self.num_sets}, base={self.base.rows}, "
                f"deltas={[d.rows for d in self.deltas]}, "
                f"plan={self.plan.driver!r}, "
                f"compactions={self.compactions})")
