import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- dry-run: lower + compile every (arch x shape x mesh) cell ------------
#
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  512 placeholder host devices exist only inside this
# process; smoke tests and benchmarks see the real single device.
#
# Usage:
#   python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
#   python -m repro.launch.dryrun --all [--jobs 3] [--mesh both]
#   python -m repro.launch.dryrun --arch bitmap-join --shape join_1m ...
#
# Per cell this prints compiled.memory_analysis() / cost_analysis() (the
# contract: proves the program fits and yields FLOPs/bytes) and writes a JSON
# blob with the loop-aware HLO analysis + roofline terms for EXPERIMENTS.md.

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES, input_specs, shape_applicable
from repro.launch import hlo_analysis, roofline
from repro.distributed.sharding import activation_sharding
from repro.launch.mesh import batch_axes, make_production_mesh, named
from repro.models import DecodeEngine, Model
from repro.train import OptimizerConfig
from repro.train import step as step_lib

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

JOIN_SHAPES = {"join_1m": dict(n_sets=1 << 20, max_len=64, b=128)}


def _sds(tree, mesh, specs):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        tree, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _batch_specs(cfg, mesh, sp, kind: str):
    axes = batch_axes(mesh)
    n_batch = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    baxis = axes if axes and sp.global_batch % n_batch == 0 else None
    out: Dict[str, P] = {}
    if cfg.frame_inputs:
        out["frame_embeds"] = P(baxis, None, None)
    else:
        out["tokens"] = P(baxis, None)
    if kind == "train":
        out["labels"] = P(baxis, None)
    if cfg.family == "vlm" and kind != "decode":
        out["image_embeds"] = P(baxis, None, None)
    return out


def lower_cell(arch: str, shape: str, mesh_name: str, *,
               opts: Optional[dict] = None):
    """Build + lower + compile one cell; returns (compiled, info dict)."""
    opts = opts or {}
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = int(np.prod(list(mesh.shape.values())))

    if arch == "bitmap-join":
        return _lower_join_cell(shape, mesh, mesh_name, opts)

    cfg = configs.get(arch)
    if opts.get("triangle"):
        pass  # handled via make_train_step flag below
    sp = SHAPES[shape]
    if not shape_applicable(cfg, shape):
        raise SystemExit(f"shape {shape} not applicable to {arch} (noted in DESIGN.md)")
    model = Model(cfg)
    engine = DecodeEngine(model)
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    pspecs = model.param_specs(mesh, fsdp=fsdp)
    bspecs = _batch_specs(cfg, mesh, sp, sp.kind)
    binputs = _sds(input_specs(cfg, shape), mesh, bspecs)

    with mesh, activation_sharding(mesh, batch_axes=fsdp,
                                    seq_parallel=opts.get("seq_parallel", False)):
        if sp.kind == "train":
            opt_cfg = OptimizerConfig(name=opts.get("optimizer", "adamw"))
            sspecs = step_lib.state_specs(model, opt_cfg, mesh, fsdp=fsdp)
            sshapes = step_lib.state_shapes(model, opt_cfg)
            state_in = _sds(sshapes, mesh, sspecs)
            fn = step_lib.make_train_step(
                model, opt_cfg,
                microbatches=opts.get("microbatches", 1),
                triangle=opts.get("triangle", False))
            jitted = jax.jit(fn, in_shardings=named(mesh, (sspecs, bspecs)),
                             out_shardings=named(mesh, (sspecs, None)),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_in, binputs)
        elif sp.kind == "prefill":
            cspecs = engine.cache_specs(mesh, sp.global_batch, fsdp=fsdp)
            logit_spec = P(bspecs[next(iter(bspecs))][0], None,
                           "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None)
            pin = _sds(model.param_shapes(), mesh, pspecs)

            def prefill_fn(params, batch):
                return engine.prefill(params, batch, max_len=sp.seq_len,
                                      last_only=True)

            jitted = jax.jit(prefill_fn,
                             in_shardings=named(mesh, (pspecs, bspecs)),
                             out_shardings=named(mesh, (logit_spec, cspecs)))
            lowered = jitted.lower(pin, binputs)
        else:  # decode
            cspecs = engine.cache_specs(mesh, sp.global_batch, fsdp=fsdp)
            cshapes = engine.cache_shapes(sp.global_batch, sp.seq_len)
            cin = _sds(cshapes, mesh, cspecs)
            pin = _sds(model.param_shapes(), mesh, pspecs)
            logit_spec = P(bspecs[next(iter(bspecs))][0], None,
                           "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None)
            jitted = jax.jit(engine.decode_step,
                             in_shardings=named(mesh, (pspecs, cspecs, bspecs)),
                             out_shardings=named(mesh, (logit_spec, cspecs)),
                             donate_argnums=(1,))
            lowered = jitted.lower(pin, cin, binputs)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    info = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "n_devices": n_dev,
        "compile_seconds": compile_s,
        "active_params": model.num_active_params(),
        "total_params": model.num_params(),
        "model_flops": roofline.model_flops_for(cfg, sp, model.num_active_params()),
    }
    return compiled, info


def _lower_join_cell(shape: str, mesh, mesh_name: str, opts: dict):
    """The paper's own workload on the production mesh: distributed ring join."""
    from repro.core.join import ring_join_sharded

    js = JOIN_SHAPES[shape]
    n_dev = int(np.prod(list(mesh.shape.values())))
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    n, l, b = js["n_sets"], js["max_len"], js["b"]
    w = b // 32
    spec = P(axes)
    tokens = jax.ShapeDtypeStruct((n, l), jnp.int32,
                                  sharding=NamedSharding(mesh, P(axes, None)))
    lengths = jax.ShapeDtypeStruct((n,), jnp.int32,
                                   sharding=NamedSharding(mesh, P(axes)))
    words = jax.ShapeDtypeStruct((n, w), jnp.uint32,
                                 sharding=NamedSharding(mesh, P(axes, None)))

    def join_fn(tok, length, word):
        return ring_join_sharded(
            tok, length, word, mesh=mesh, axis=axes, sim="jaccard",
            tau=0.8, impl=opts.get("join_impl", "ref"),
            capacity_per_step=opts.get("capacity", 2048))

    with mesh:
        lowered = jax.jit(join_fn).lower(tokens, lengths, words)
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
    # model_flops for the join: the bitmap-filter work itself — xor+popcount
    # over all in-window pairs ~ N^2/2 pairs x (b/32 words x ~4 ops) treated
    # as the useful work; verification excluded.
    pairs = 0.5 * n * n
    info = {
        "arch": "bitmap-join", "shape": shape, "mesh": mesh_name,
        "n_devices": n_dev, "compile_seconds": compile_s,
        "active_params": 0, "total_params": 0,
        "model_flops": pairs * (w * 4.0),
    }
    return compiled, info


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: str,
             opts: Optional[dict] = None, tag: str = "") -> dict:
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name, "ok": False}
    try:
        compiled, info = lower_cell(arch, shape, mesh_name, opts=opts)
        rec.update(info)
        ma = compiled.memory_analysis()
        print(f"== memory_analysis [{arch} {shape} {mesh_name}] ==")
        print(ma)
        mem = {}
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem[f] = getattr(ma, f, None)
        rec["memory"] = mem
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        print(f"== cost_analysis (flops/bytes, loop bodies counted once) ==")
        if ca:
            print({k: v for k, v in ca.items() if k in ("flops", "bytes accessed")})
            rec["xla_cost"] = {"flops": ca.get("flops"),
                               "bytes_accessed": ca.get("bytes accessed")}
        txt = compiled.as_text()
        costs = hlo_analysis.analyze(txt)
        rec["hlo"] = {
            "flops_per_device": costs.flops,
            "hbm_bytes_per_device": costs.hbm_bytes,
            "collective_traffic_per_device": costs.collective_traffic,
            "collectives": [dataclasses.asdict(c) for c in costs.collectives[:20]],
            "per_opcode_flops": costs.per_opcode_flops,
        }
        rl = roofline.compute_roofline(
            arch=arch, shape=shape, mesh_name=mesh_name,
            n_devices=info["n_devices"], costs=costs,
            model_flops=info["model_flops"])
        rec["roofline"] = rl.as_dict()
        rec["ok"] = True
        print(f"== roofline == t_comp={rl.t_compute*1e3:.2f}ms "
              f"t_mem={rl.t_memory*1e3:.2f}ms t_coll={rl.t_collective*1e3:.2f}ms "
              f"bottleneck={rl.bottleneck} useful={rl.useful_ratio:.3f} "
              f"frac={rl.roofline_fraction:.3f}")
    except SystemExit as e:
        rec["skipped"] = str(e)
        rec["ok"] = True
        print(f"SKIP {arch} {shape} {mesh_name}: {e}")
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"FAIL {arch} {shape} {mesh_name}: {rec['error']}")
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)
    print("wrote", path)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--tag", default="")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--triangle", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--join-impl", default="ref")
    ap.add_argument("--optimizer", default="adamw")
    args = ap.parse_args()
    opts = {"microbatches": args.microbatches, "triangle": args.triangle,
            "optimizer": args.optimizer, "seq_parallel": args.seq_parallel,
            "join_impl": args.join_impl}

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = []
        for arch in configs.ARCHS:
            cfg = configs.get(arch)
            for shape in SHAPES:
                if shape_applicable(cfg, shape):
                    for m in meshes:
                        cells.append((arch, shape, m))
        cells.append(("bitmap-join", "join_1m", meshes[0]))
        _drive(cells, args)
        return

    assert args.arch and args.shape
    for m in meshes:
        run_cell(args.arch, args.shape, m, args.out, opts=opts, tag=args.tag)


def _drive(cells, args) -> None:
    """Run cells in subprocesses (fresh XLA per cell; bounded parallelism)."""
    procs: list = []
    results = []

    def launch(cell):
        arch, shape, m = cell
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", m,
               "--out", args.out]
        if args.tag:
            cmd += ["--tag", args.tag]
        logf = open(os.path.join(args.out, f"log_{arch}__{shape}__{m}.txt"), "w")
        return cell, subprocess.Popen(cmd, stdout=logf, stderr=subprocess.STDOUT), logf

    os.makedirs(args.out, exist_ok=True)
    queue = list(cells)
    while queue or procs:
        while queue and len(procs) < args.jobs:
            procs.append(launch(queue.pop(0)))
        for entry in list(procs):
            cell, p, logf = entry
            if p.poll() is not None:
                procs.remove(entry)
                logf.close()
                results.append((cell, p.returncode))
                print(f"[{len(results)}/{len(cells)}] {cell} rc={p.returncode}")
        time.sleep(1.0)
    bad = [c for c, rc in results if rc != 0]
    print(f"done: {len(results) - len(bad)}/{len(results)} cells ok; failures: {bad}")


if __name__ == "__main__":
    main()
