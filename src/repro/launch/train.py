"""End-to-end training driver.

Runs a real training loop (reduced configs train on this CPU container;
full configs are for the dry-run/mesh path) with the production substrate:
dedup'd data pipeline, pjit train step, async sharded checkpointing and the
fault-tolerant runner.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 200 --batch 8 --seq 128

XLA latency-hiding knobs used on real TPU deployments are recorded here so
the launcher is copy-paste ready:
  --xla_tpu_enable_latency_hiding_scheduler=true
  --xla_tpu_overlap_compute_collective_tc=true
  --xla_tpu_data_parallel_opt_different_sized_ops=true
"""

from __future__ import annotations

import argparse
import logging
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.loader import LoaderConfig, SyntheticLMLoader
from repro.distributed import CheckpointManager, FaultTolerantRunner, RunnerConfig
from repro.distributed.sharding import activation_sharding
from repro.launch.mesh import make_mesh, named
from repro.models import Model
from repro.train import OptimizerConfig
from repro.train import step as step_lib

log = logging.getLogger("repro.train")


def train_main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the tiny same-family smoke config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="1x1", help="DxM fake mesh, e.g. 2x2")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    model = Model(cfg)
    opt_cfg = OptimizerConfig(name=args.optimizer, learning_rate=args.lr,
                              warmup_steps=max(args.steps // 20, 5),
                              decay_steps=args.steps)

    d, m = (int(x) for x in args.mesh.split("x"))
    n_need = d * m
    n_have = len(jax.devices())
    if n_need > n_have:
        raise SystemExit(
            f"mesh {args.mesh} needs {n_need} devices, have {n_have} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count={n_need})")
    mesh = make_mesh((d, m), ("data", "model"))
    fsdp = ("data",)

    loader = SyntheticLMLoader(
        cfg, LoaderConfig(batch_size=args.batch, seq_len=args.seq,
                          vocab_size=cfg.vocab_size),
        mesh=mesh, batch_axes=fsdp)
    ckpt = CheckpointManager(args.ckpt_dir)

    def make_state(_mesh_unused):
        from jax.sharding import NamedSharding

        sspecs = step_lib.state_specs(model, opt_cfg, mesh, fsdp=fsdp)
        shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp), sspecs,
                                 is_leaf=lambda x: isinstance(x, type(sspecs["step"])))
        with mesh:
            state = jax.jit(
                lambda: step_lib.init_state(model, opt_cfg, jax.random.PRNGKey(0)),
                out_shardings=shardings)()
        return state, shardings

    step_fn_raw = step_lib.make_train_step(model, opt_cfg,
                                           microbatches=args.microbatches)
    sspecs = step_lib.state_specs(model, opt_cfg, mesh, fsdp=fsdp)
    bspecs = step_lib.batch_specs(model, mesh, batch_axes=fsdp)
    with mesh, activation_sharding(mesh, batch_axes=fsdp):
        jitted = jax.jit(step_fn_raw,
                         in_shardings=named(mesh, (sspecs, bspecs)),
                         out_shardings=named(mesh, (sspecs, None)),
                         donate_argnums=(0,))

    history = []

    def step_fn(state, batch):
        with mesh:
            state, metrics = jitted(state, batch)
        s = int(state["step"])
        if s % args.log_every == 0 or s == 1:
            m_host = {k: float(v) for k, v in metrics.items()}
            history.append((s, m_host))
            log.info("step %d: %s", s,
                     {k: round(v, 4) for k, v in m_host.items()})
            print(f"step {s}: loss={m_host['loss']:.4f} "
                  f"gnorm={m_host['grad_norm']:.3f} lr={m_host['lr']:.2e}")
        return state, metrics

    runner = FaultTolerantRunner(
        step_fn, make_state, iter(loader), ckpt,
        RunnerConfig(checkpoint_every=args.ckpt_every))
    t0 = time.time()
    out = runner.run(args.steps)
    dt = time.time() - t0
    final_loss = history[-1][1]["loss"] if history else float("nan")
    print(f"trained {args.steps} steps in {dt:.1f}s; final loss {final_loss:.4f}; "
          f"restarts={out['restarts']}")
    return out, history


if __name__ == "__main__":
    logging.basicConfig(level=logging.INFO)
    train_main()
