"""Post-SPMD HLO analysis: loop-aware FLOPs, HBM traffic and collective bytes.

``compiled.cost_analysis()`` counts each ``while`` body exactly once, which
under-reports scan-over-layers models by ~num_layers x.  This module parses
``compiled.as_text()`` instead and:

* builds the computation call graph (while bodies via
  ``backend_config={"known_trip_count":...}``, ``call``/fusion edges),
* accumulates a trip-count **multiplier** per computation,
* counts per-computation
  - dot/convolution FLOPs (2 x result x contracted dims — the MXU term),
  - HBM traffic at fusion granularity (operand reads + result writes),
  - collective traffic per op with replica-group sizes, using per-device ring
    formulas: all-gather (g-1)/g x result, all-reduce 2(g-1)/g x result,
    reduce-scatter (g-1) x result, all-to-all (g-1)/g x result,
    collective-permute 1 x result.

Everything is per-device (the module is the partitioned program).  Validated
against ``cost_analysis`` on loop-free programs in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_shape: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, str]  # op name -> result shape (params included)


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{")
_SHAPE_TOK = r"(?:\w+\[[\d,]*\](?:\{[^}]*\})?)"
_SHAPE_FULL = rf"(?:{_SHAPE_TOK}|\((?:[^()]|\([^()]*\))*\))"
_OP_LINE = re.compile(
    rf"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*({_SHAPE_FULL})\s+([\w\-]+)\(")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_CALLEE = re.compile(r"(?:body|to_apply|calls)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            # parameter declarations: "%p = f32[..] parameter(0)" match too;
            # anything else (attributes continuation) is skipped.
            continue
        name, shape, opcode = m.group(1), m.group(2).strip(), m.group(3)
        paren = line[m.end():]
        operands = _OPERAND.findall(paren.split("),")[0] if ")," in paren else paren)
        op = Op(name=name, opcode=opcode, result_shape=shape, operands=operands, line=line)
        cur.ops.append(op)
        cur.shapes[name] = shape
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Trip-count multiplier per computation via call-graph walk from ENTRY."""
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
    if entry is None:  # fall back: the last computation is usually the entry
        entry = list(comps)[-1]
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # Repeated relaxation (call graph is a DAG of modest depth).
    for _ in range(16):
        changed = False
        for cname, comp in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for op in comp.ops:
                if op.opcode == "while":
                    trips = 1
                    tm = _TRIP.search(op.line)
                    if tm:
                        trips = int(tm.group(1))
                    body = _CALLEE.search(op.line)
                    cond = _COND.search(op.line)
                    for target, k in ((body, trips), (cond, trips + 1)):
                        if target and mult.get(target.group(1), 0.0) < m * k:
                            mult[target.group(1)] = m * k
                            changed = True
                elif op.opcode in ("call", "fusion", "custom-call", "reduce",
                                   "conditional", "map", "sort", "scatter",
                                   "select-and-scatter", "reduce-window"):
                    cm = _CALLEE.search(op.line)
                    if cm and mult.get(cm.group(1), 0.0) < m:
                        mult[cm.group(1)] = m
                        changed = True
        if not changed:
            break
    return dict(mult)


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    """2 x prod(result dims) x prod(lhs contracted dims)."""
    out_elems = _shape_elems(op.result_shape)
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not mc or not op.operands:
        return 2.0 * out_elems  # degenerate
    lhs_shape = shapes.get(op.operands[0], "")
    sm = _SHAPE_RE.search(lhs_shape)
    if not sm:
        return 2.0 * out_elems
    dims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
    contracted = 1
    for idx in mc.group(1).split(","):
        if idx != "" and int(idx) < len(dims):
            contracted *= dims[int(idx)]
    return 2.0 * out_elems * contracted


def _conv_flops(op: Op, shapes: Dict[str, str]) -> float:
    out_elems = _shape_elems(op.result_shape)
    if len(op.operands) < 2:
        return 2.0 * out_elems
    kshape = shapes.get(op.operands[1], "")
    kelems = _shape_elems(kshape)
    # rough: 2 * out * (kernel elems / out-channels)
    return 2.0 * out_elems * max(kelems, 1) ** 0.5


_SKIP_BYTES_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota", "while", "call",
    "conditional",
}


@dataclasses.dataclass
class CollectiveInfo:
    opcode: str
    group_size: int
    result_bytes: int
    traffic_bytes: float   # per device, ring model
    count: float           # including loop multipliers
    example: str = ""


@dataclasses.dataclass
class HloCosts:
    flops: float                       # per device, loop-aware
    hbm_bytes: float                   # per device, fusion-granularity R+W
    collective_traffic: float          # per device bytes on the wire
    collectives: List[CollectiveInfo]
    per_opcode_flops: Dict[str, float]


def _collective_traffic(opcode: str, g: int, result_bytes: int) -> float:
    if g <= 1:
        return 0.0
    if opcode.startswith("all-gather"):
        return (g - 1) / g * result_bytes
    if opcode.startswith("all-reduce"):
        return 2.0 * (g - 1) / g * result_bytes
    if opcode.startswith("reduce-scatter"):
        return (g - 1) * result_bytes
    if opcode.startswith("all-to-all"):
        return (g - 1) / g * result_bytes
    if opcode.startswith("collective-permute"):
        return float(result_bytes)
    return 0.0


def _fusion_computations(comps: Dict[str, Computation]) -> set:
    """Computations called by fusion ops — their buffers are fused away."""
    out = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                cm = _CALLEE.search(op.line)
                if cm:
                    out.add(cm.group(1))
    return out


def analyze(text: str, default_group: int = 1) -> HloCosts:
    comps = parse_module(text)
    mult = _multipliers(comps)
    fusion_comps = _fusion_computations(comps)
    flops = 0.0
    hbm = 0.0
    per_opcode: Dict[str, float] = defaultdict(float)
    coll: Dict[Tuple[str, int, int], CollectiveInfo] = {}

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        fusion_comp = cname in fusion_comps
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                f = _dot_flops(op, comp.shapes) * m
                flops += f
                per_opcode["dot"] += f
            elif oc == "convolution":
                f = _conv_flops(op, comp.shapes) * m
                flops += f
                per_opcode["convolution"] += f
            base = oc.replace("-start", "")
            if base in _COLLECTIVES and not oc.endswith("-done"):
                rb = _shape_bytes(op.result_shape)
                g = default_group
                gm = _GROUPS_IOTA.search(op.line)
                if gm:
                    g = int(gm.group(2))
                else:
                    gl = _GROUPS_LIST.search(op.line)
                    if gl:
                        g = len(gl.group(1).split(","))
                key = (base, g, rb)
                if key not in coll:
                    coll[key] = CollectiveInfo(
                        opcode=base, group_size=g, result_bytes=rb,
                        traffic_bytes=0.0, count=0.0, example=op.line.strip()[:160])
                coll[key].count += m
                coll[key].traffic_bytes += _collective_traffic(base, g, rb) * m
            # HBM traffic: fusion-granularity writes + reads.  Skip ops inside
            # fusion computations (their buffers are fused away).
            if not fusion_comp and oc not in _SKIP_BYTES_OPS:
                if oc == "dynamic-update-slice":
                    # In-place aliasing: traffic = the updated slice (r+w),
                    # not the full buffer.
                    upd_bytes = _shape_bytes(comp.shapes.get(op.operands[1], ""))                         if len(op.operands) > 1 else 0
                    hbm += 2 * upd_bytes * m
                else:
                    w = _shape_bytes(op.result_shape)
                    r = sum(_shape_bytes(comp.shapes.get(o, "")) for o in op.operands)
                    hbm += (w + r) * m

    total_coll = sum(c.traffic_bytes for c in coll.values())
    return HloCosts(
        flops=flops,
        hbm_bytes=hbm,
        collective_traffic=total_coll,
        collectives=sorted(coll.values(), key=lambda c: -c.traffic_bytes),
        per_opcode_flops=dict(per_opcode),
    )
