"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — ``pod``
composes with ``data`` into the batch/FSDP axis; ``model`` (TP/EP) stays
intra-pod on ICI.  Scaling to N pods changes one integer here.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS first.
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` only where the installed JAX has it (added post-0.4)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh helper (tests use small fake-device meshes)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes), **_axis_type_kwargs(len(axes)))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def named(mesh, spec_tree):
    """Convert a tree of PartitionSpecs to NamedShardings for this mesh."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    return jax.tree.map(
        lambda sp: NamedSharding(mesh, sp) if isinstance(sp, P) else sp,
        spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None)
