"""Aggregate dry-run JSON cells into the EXPERIMENTS.md roofline tables."""

from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List


def load(out_dir: str) -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}GiB"


def roofline_table(recs: List[dict], mesh: str, tag: str = "") -> str:
    rows = []
    head = ("| arch | shape | per-dev FLOPs | per-dev HBM B | coll B | "
            "t_comp | t_mem | t_coll | bound | bottleneck | 6ND/HLO | frac |")
    sep = "|" + "---|" * 12
    for r in recs:
        if r.get("mesh") != mesh or not r.get("ok") or "roofline" not in r:
            continue
        if bool(tag) != ("tag" in r.get("_tag", "")):
            pass
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['flops_per_device']:.2e} | "
            f"{rl['hbm_bytes_per_device']:.2e} | {rl['collective_bytes_per_device']:.2e} | "
            f"{rl['t_compute']*1e3:.1f}ms | {rl['t_memory']*1e3:.1f}ms | "
            f"{rl['t_collective']*1e3:.1f}ms | {rl['step_time_bound']*1e3:.1f}ms | "
            f"{rl['bottleneck']} | {rl['useful_ratio']:.3f} | "
            f"{rl['roofline_fraction']:.3f} |")
    skips = [r for r in recs if r.get("mesh") == mesh and "skipped" in r]
    out = [head, sep] + rows
    if skips:
        out.append("")
        for r in skips:
            out.append(f"- SKIP {r['arch']} x {r['shape']}: {r['skipped']}")
    return "\n".join(out)


def memory_table(recs: List[dict], mesh: str) -> str:
    head = "| arch | shape | args/dev | temp/dev | fits 16GiB HBM? | compile_s |"
    sep = "|" + "---|" * 6
    rows = []
    for r in recs:
        if r.get("mesh") != mesh or not r.get("ok") or "memory" not in r:
            continue
        m = r["memory"]
        args = m.get("argument_size_in_bytes") or 0
        temp = m.get("temp_size_in_bytes") or 0
        alias = m.get("alias_size_in_bytes") or 0
        tot = args + temp - 0  # aliased outputs reuse argument space
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_bytes(args)} | {fmt_bytes(temp)} | "
            f"{'yes' if tot < 16*2**30 else 'NO'} | {r.get('compile_seconds', 0):.0f} |")
    return "\n".join([head, sep] + rows)


def pick_hillclimb(recs: List[dict]) -> List[str]:
    singles = [r for r in recs if r.get("mesh") == "single" and r.get("ok")
               and "roofline" in r]
    worst_frac = min(singles, key=lambda r: r["roofline"]["roofline_fraction"])
    most_coll = max(singles, key=lambda r: r["roofline"]["t_collective"] /
                    max(r["roofline"]["step_time_bound"], 1e-12))
    lines = [
        f"worst roofline fraction: {worst_frac['arch']} x {worst_frac['shape']} "
        f"(frac={worst_frac['roofline']['roofline_fraction']:.4f})",
        f"most collective-bound: {most_coll['arch']} x {most_coll['shape']} "
        f"(t_coll share={most_coll['roofline']['t_collective']/max(most_coll['roofline']['step_time_bound'],1e-12):.2f})",
        "paper-representative: bitmap-join x join_1m (the paper's own workload)",
    ]
    return lines


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(out_dir)
    for mesh in ("single", "multi"):
        n_ok = sum(1 for r in recs if r.get("mesh") == mesh and r.get("ok"))
        print(f"\n### Roofline — {mesh} mesh ({n_ok} cells)\n")
        print(roofline_table(recs, mesh))
        print(f"\n### Memory — {mesh} mesh\n")
        print(memory_table(recs, mesh))
    print("\n### Hillclimb candidates\n")
    for l in pick_hillclimb(recs):
        print("-", l)


if __name__ == "__main__":
    main()
