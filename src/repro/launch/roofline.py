"""Three-term roofline model from compiled dry-run artifacts.

Hardware constants (assignment: TPU v5e-class):
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: ~50 GB/s per link; an axis-collective uses the
                      bidirectional ring (2 links) of that torus axis.

Terms (seconds per step, per device):
  t_compute    = HLO_FLOPs / 197e12
  t_memory     = HLO_bytes / 819e9
  t_collective = collective_traffic / (2 * 50e9)

with HLO_FLOPs / HLO_bytes / collective traffic computed *loop-aware* by
``hlo_analysis`` (XLA's ``cost_analysis`` counts while bodies once; we
multiply by known trip counts).  The dominant term is the bottleneck; the
roofline fraction reported in EXPERIMENTS.md is
``t_compute / max(t_compute, t_memory, t_collective)`` (how close the step is
to being compute-bound at peak).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.launch.hlo_analysis import HloCosts

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link
LINKS_PER_COLLECTIVE = 2   # bidirectional ring on one torus axis


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float                  # 6·N_active·D (train) etc., global
    useful_ratio: float                 # model_flops / (flops_per_device * n)
    roofline_fraction: float            # t_compute / max(terms)
    step_time_bound: float              # max of terms (no-overlap bound)
    notes: str = ""

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def compute_roofline(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_devices: int,
    costs: HloCosts,
    model_flops: float,
    notes: str = "",
) -> Roofline:
    t_c = costs.flops / PEAK_FLOPS
    t_m = costs.hbm_bytes / HBM_BW
    t_x = costs.collective_traffic / (LINKS_PER_COLLECTIVE * LINK_BW)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    hlo_global = costs.flops * n_devices
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=costs.flops,
        hbm_bytes_per_device=costs.hbm_bytes,
        collective_bytes_per_device=costs.collective_traffic,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / hlo_global) if hlo_global else 0.0,
        roofline_fraction=(t_c / max(max(terms.values()), 1e-30)),
        step_time_bound=max(terms.values()),
        notes=notes,
    )


@dataclasses.dataclass
class KernelRoofline:
    """Achieved-vs-peak report for one compiled kernel/step.

    ``flops``/``hbm_bytes`` come from :func:`repro.launch.hlo_analysis.analyze`
    over the compiled module; ``achieved_*`` divide them by the measured wall
    time, and the ``*_frac`` columns compare that against the v5e-class peaks
    above.  ``bound_us`` is the no-overlap roofline lower bound — the wall
    time the program could not beat even at peak; ``gap`` = measured/bound is
    the headroom the kernel leaves on the table (the number the tentpole
    optimizations attack).  On a CPU container the fractions are tiny — the
    point is the *relative* trajectory and the bottleneck term, not absolute
    TPU numbers.
    """
    name: str
    us_measured: float
    flops: float
    hbm_bytes: float
    t_compute: float
    t_memory: float
    bottleneck: str
    achieved_flops_s: float
    achieved_bytes_s: float
    flops_frac: float
    bytes_frac: float
    bound_us: float
    gap: float

    def columns(self) -> str:
        """The roofline columns appended to a benchmark row's derived field."""
        return (f"flops={self.flops:.3g} bytes={self.hbm_bytes:.3g} "
                f"ach_flops={self.achieved_flops_s:.3g}/{PEAK_FLOPS:.3g} "
                f"ach_bytes={self.achieved_bytes_s:.3g}/{HBM_BW:.3g} "
                f"bottleneck={self.bottleneck} "
                f"bound_us={self.bound_us:.1f} gap={self.gap:.3g}")

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def kernel_roofline(name: str, costs: HloCosts, us_measured: float) -> KernelRoofline:
    """Roofline report for a single-device kernel: reuse the three-term model
    (collective term is zero for kernels) against the measured wall time."""
    r = compute_roofline(
        arch="kernel", shape=name, mesh_name="1x1", n_devices=1,
        costs=costs, model_flops=costs.flops)
    sec = max(us_measured, 1e-3) / 1e6
    bound = max(r.step_time_bound, 1e-30)
    return KernelRoofline(
        name=name,
        us_measured=us_measured,
        flops=costs.flops,
        hbm_bytes=costs.hbm_bytes,
        t_compute=r.t_compute,
        t_memory=r.t_memory,
        bottleneck=r.bottleneck,
        achieved_flops_s=costs.flops / sec,
        achieved_bytes_s=costs.hbm_bytes / sec,
        flops_frac=(costs.flops / sec) / PEAK_FLOPS,
        bytes_frac=(costs.hbm_bytes / sec) / HBM_BW,
        bound_us=bound * 1e6,
        gap=sec / bound,
    )


def model_flops_for(cfg, shape_spec, active_params: int) -> float:
    """MODEL_FLOPS per step (global): 6·N·D train, 2·N·D prefill, 2·N·B decode."""
    b, s = shape_spec.global_batch, shape_spec.seq_len
    if shape_spec.kind == "train":
        return 6.0 * active_params * b * s
    if shape_spec.kind == "prefill":
        return 2.0 * active_params * b * s
    return 2.0 * active_params * b  # decode: one token per sequence
