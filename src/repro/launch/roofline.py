"""Three-term roofline model from compiled dry-run artifacts.

Hardware constants (assignment: TPU v5e-class):
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: ~50 GB/s per link; an axis-collective uses the
                      bidirectional ring (2 links) of that torus axis.

Terms (seconds per step, per device):
  t_compute    = HLO_FLOPs / 197e12
  t_memory     = HLO_bytes / 819e9
  t_collective = collective_traffic / (2 * 50e9)

with HLO_FLOPs / HLO_bytes / collective traffic computed *loop-aware* by
``hlo_analysis`` (XLA's ``cost_analysis`` counts while bodies once; we
multiply by known trip counts).  The dominant term is the bottleneck; the
roofline fraction reported in EXPERIMENTS.md is
``t_compute / max(t_compute, t_memory, t_collective)`` (how close the step is
to being compute-bound at peak).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.launch.hlo_analysis import HloCosts

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link
LINKS_PER_COLLECTIVE = 2   # bidirectional ring on one torus axis


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float                  # 6·N_active·D (train) etc., global
    useful_ratio: float                 # model_flops / (flops_per_device * n)
    roofline_fraction: float            # t_compute / max(terms)
    step_time_bound: float              # max of terms (no-overlap bound)
    notes: str = ""

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def compute_roofline(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_devices: int,
    costs: HloCosts,
    model_flops: float,
    notes: str = "",
) -> Roofline:
    t_c = costs.flops / PEAK_FLOPS
    t_m = costs.hbm_bytes / HBM_BW
    t_x = costs.collective_traffic / (LINKS_PER_COLLECTIVE * LINK_BW)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    hlo_global = costs.flops * n_devices
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=costs.flops,
        hbm_bytes_per_device=costs.hbm_bytes,
        collective_bytes_per_device=costs.collective_traffic,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / hlo_global) if hlo_global else 0.0,
        roofline_fraction=(t_c / max(max(terms.values()), 1e-30)),
        step_time_bound=max(terms.values()),
        notes=notes,
    )


def model_flops_for(cfg, shape_spec, active_params: int) -> float:
    """MODEL_FLOPS per step (global): 6·N·D train, 2·N·D prefill, 2·N·B decode."""
    b, s = shape_spec.global_batch, shape_spec.seq_len
    if shape_spec.kind == "train":
        return 6.0 * active_params * b * s
    if shape_spec.kind == "prefill":
        return 2.0 * active_params * b * s
    return 2.0 * active_params * b  # decode: one token per sequence
