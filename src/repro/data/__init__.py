"""Data pipeline: synthetic collections (paper §5 methodology), shingling,
bitmap-join dedup stage, checkpointable LM loader."""

from repro.data.collections import (
    dblp_like_collection,
    skewed_collection,
    uniform_collection,
    with_duplicates,
    zipf_collection,
)
from repro.data.dedup import (
    dedup_against,
    dedup_collection,
    dedup_documents,
    dedup_shards,
    shingle,
)
from repro.data.loader import LoaderConfig, SyntheticLMLoader
