"""Deterministic, checkpointable LM batch pipeline.

Synthetic-corpus loader shaped like a production pipeline:

* documents are generated (or supplied), **deduplicated** with the bitmap
  join stage, then packed into fixed-length sequences;
* batches are sharded over the mesh batch axes via
  ``jax.make_array_from_callback`` (each host materialises only its shard);
* iteration state is a tiny dict (epoch, cursor, rng key) — saved alongside
  model checkpoints so restarts resume mid-epoch without replaying data;
* deterministic: (seed, state) fully determine every future batch, which is
  what makes failure-recovery reproducible.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class LoaderConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0
    vocab_size: int = 256


class SyntheticLMLoader:
    """Deterministic synthetic token stream with checkpointable cursor."""

    def __init__(self, model_cfg: ModelConfig, cfg: LoaderConfig,
                 mesh=None, batch_axes=("pod", "data")):
        self.model_cfg = model_cfg
        self.cfg = cfg
        self.mesh = mesh
        self.batch_axes = tuple(a for a in batch_axes if mesh and a in mesh.shape)
        self.state: Dict[str, Any] = {"step": 0, "seed": cfg.seed}

    # --- checkpointable state ---
    def state_dict(self) -> Dict[str, Any]:
        return dict(self.state)

    def load_state_dict(self, st: Dict[str, Any]) -> None:
        self.state = dict(st)

    # --- deterministic batch synthesis ---
    def _host_batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg, mc = self.cfg, self.model_cfg
        rng = np.random.default_rng((self.state["seed"], step))
        b, s = cfg.batch_size, cfg.seq_len
        v = min(cfg.vocab_size, mc.vocab_size)
        out: Dict[str, np.ndarray] = {}
        toks = rng.integers(0, v, size=(b, s + 1), dtype=np.int32)
        if mc.frame_inputs:
            emb = rng.normal(size=(b, s, mc.d_model)).astype(np.float32)
            out["frame_embeds"] = emb.astype(jnp.bfloat16)
        else:
            out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
        if mc.family == "vlm":
            out["image_embeds"] = rng.normal(
                size=(b, mc.num_image_tokens, mc.d_model)).astype(np.float32).astype(jnp.bfloat16)
        return out

    def _shard(self, batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
        if self.mesh is None or not self.batch_axes:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        from jax.sharding import NamedSharding, PartitionSpec as P

        out = {}
        for k, v in batch.items():
            spec = P(self.batch_axes, *([None] * (v.ndim - 1)))
            sh = NamedSharding(self.mesh, spec)
            out[k] = jax.make_array_from_callback(
                v.shape, sh, lambda idx, vv=v: vv[idx])
        return out

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        batch = self._host_batch(self.state["step"])
        self.state["step"] += 1
        return self._shard(batch)
