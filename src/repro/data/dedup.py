"""Near-duplicate dedup: the paper's join as a first-class pipeline stage.

Documents -> shingled token sets -> exact set-similarity self-join (Bitmap
Filter accelerated) -> union-find over similar pairs -> keep one doc per
duplicate cluster.  This is the LM-corpus deployment of the paper's
technique: exact Jaccard near-dup detection before packing/batching.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.collection import Collection, from_lists
from repro.core.constants import JACCARD
from repro.core.engine import PreparedCollection
from repro.core.join import blocked_bitmap_join, JoinStats


def shingle(text: str, width: int = 5, vocab_bits: int = 30) -> List[int]:
    """Character-w-shingles hashed into a bounded token universe."""
    if len(text) < width:
        return [hash(text) % (1 << vocab_bits)]
    out = {hash(text[i:i + width]) % (1 << vocab_bits)
           for i in range(len(text) - width + 1)}
    return sorted(out)


def token_shingles(tokens: Sequence[int], width: int = 8,
                   vocab_bits: int = 30) -> List[int]:
    """w-gram shingles over a token stream (for already-tokenised corpora)."""
    t = tuple(tokens)
    if len(t) < width:
        return [hash(t) % (1 << vocab_bits)]
    out = {hash(t[i:i + width]) % (1 << vocab_bits)
           for i in range(len(t) - width + 1)}
    return sorted(out)


class _UnionFind:
    def __init__(self, n: int):
        self.parent = list(range(n))

    def find(self, a: int) -> int:
        while self.parent[a] != a:
            self.parent[a] = self.parent[self.parent[a]]
            a = self.parent[a]
        return a

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[max(ra, rb)] = min(ra, rb)


@dataclasses.dataclass
class DedupResult:
    keep: np.ndarray          # indices of retained documents
    drop: np.ndarray          # indices removed as near-duplicates
    pairs: np.ndarray         # the similar pairs found (int64[K, 2])
    stats: JoinStats


def dedup_collection(col: Collection | PreparedCollection, tau: float = 0.8,
                     *, b: int = 128, block: int = 4096, impl: str = "auto",
                     compaction: str = "device") -> DedupResult:
    """Exact near-dup removal at Jaccard >= tau. Keeps the smallest index of
    each duplicate cluster (deterministic).

    Runs the device-resident join by default: candidate compaction and
    verification stay on the accelerator, so per-block traffic is a small
    compacted pair buffer instead of a dense bool tile — the difference
    between feasible and not at corpus scale.  Accepts a
    :class:`~repro.core.engine.PreparedCollection` to reuse its cached length
    sort and bitmap words (e.g. when the same corpus is deduped at several
    thresholds); pairs/keep/drop are always in original indices.
    """
    pairs, stats = blocked_bitmap_join(
        col, JACCARD, tau, b=b, block=block, impl=impl,
        compaction=compaction, return_stats=True)
    uf = _UnionFind(col.num_sets)
    for i, j in pairs:
        uf.union(int(i), int(j))
    roots = np.array([uf.find(i) for i in range(col.num_sets)])
    keep_mask = roots == np.arange(col.num_sets)
    keep = np.nonzero(keep_mask)[0]
    drop = np.nonzero(~keep_mask)[0]
    return DedupResult(keep=keep, drop=drop, pairs=pairs, stats=stats)


def dedup_documents(texts: Sequence[str], tau: float = 0.8,
                    width: int = 5, **kw) -> Tuple[List[str], DedupResult]:
    col = from_lists([shingle(t, width) for t in texts])
    res = dedup_collection(col, tau, **kw)
    return [texts[i] for i in res.keep], res


# ---------------------------------------------------------------------------
# Incremental (R×S) dedup: new shard vs existing corpus
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IncrementalDedupResult:
    keep: np.ndarray             # indices of ``new`` retained
    drop_vs_corpus: np.ndarray   # indices of ``new`` similar to a corpus doc
    drop_within: np.ndarray      # indices of ``new`` dropped as internal dups
    pairs_rs: np.ndarray         # (corpus_index, new_index) similar pairs
    stats_rs: JoinStats


def dedup_against(corpus: Collection | PreparedCollection, new: Collection,
                  tau: float = 0.8, *,
                  b: int = 128, block: int = 4096, impl: str = "auto",
                  within: bool = True,
                  compaction: str = "device") -> IncrementalDedupResult:
    """Dedup a new shard against an already-deduped corpus (R×S join).

    Any set in ``new`` at Jaccard >= tau to a corpus set is dropped (the
    corpus copy wins); survivors are then optionally self-deduped.  Both
    collections must live in one token space (same shingler / tokenizer run).
    Uses the device-resident compaction path by default (see
    :func:`dedup_collection`).

    When streaming many shards against one corpus, pass
    ``prepare(corpus)`` (a :class:`~repro.core.engine.PreparedCollection`)
    once and reuse it across calls: the corpus length sort, bitmap words and
    length windows are then built a single time instead of per shard —
    exactly the amortization ``benchmarks/bench_engine.py`` measures.

    ``corpus`` may also be a live :class:`repro.store.CorpusStore`: the
    R×S join then runs the store's segment-union probe under the *store's*
    plan (``b``/``block``/``impl``/``compaction`` here only govern the
    optional within-shard pass), and ``pairs_rs`` column 0 holds
    store-global document ids — covering documents appended after the
    store's base was sealed, which is what closes the cross-shard leak in
    :func:`dedup_shards`.
    """
    from repro.core.engine import _as_store

    if isinstance(new, PreparedCollection):
        # Survivor sub-collections below index ``new`` by original position.
        new = new.source
    store = _as_store(corpus)
    if store is not None:
        if store.sim != JACCARD or store.tau != float(tau):
            raise ValueError(
                f"store joins at (sim={store.sim}, tau={store.tau}); "
                f"dedup_against was asked for (jaccard, {tau})")
        pairs_rs, stats_rs = store.probe(new)
    else:
        pairs_rs, stats_rs = blocked_bitmap_join(
            corpus, new, JACCARD, tau, b=b, block=block, impl=impl,
            compaction=compaction, return_stats=True)
    dup_vs_corpus = (np.unique(pairs_rs[:, 1]) if len(pairs_rs)
                     else np.zeros((0,), dtype=np.int64))
    mask = np.ones(new.num_sets, dtype=bool)
    mask[dup_vs_corpus] = False
    survivors = np.nonzero(mask)[0]
    drop_within = np.zeros((0,), dtype=np.int64)
    keep = survivors
    if within and len(survivors):
        sub = Collection(tokens=new.tokens[survivors],
                         lengths=new.lengths[survivors])
        res = dedup_collection(sub, tau, b=b, block=block, impl=impl,
                               compaction=compaction)
        keep = survivors[res.keep]
        drop_within = survivors[res.drop]
    return IncrementalDedupResult(
        keep=keep, drop_vs_corpus=dup_vs_corpus, drop_within=drop_within,
        pairs_rs=pairs_rs, stats_rs=stats_rs)


def dedup_shards(corpus: Collection | PreparedCollection,
                 shards: Sequence[Collection], tau: float = 0.8, *,
                 return_store: bool = False, policy=None,
                 **kw):
    """Stream many shards against one corpus, preparing the corpus once.

    Each shard is deduped against the *live* corpus — the original base
    **plus every prior shard's survivors**, which are sealed as
    :class:`repro.store.CorpusStore` delta segments as the stream advances.
    (This function used to join each shard against the original corpus
    only, so a duplicate pair spanning two shards survived in both — the
    cross-shard leak pinned by ``tests/test_store.py``.)  The base corpus
    artifacts are still built exactly once across the whole stream (only
    each small survivor delta is prepared), and the store's compaction
    ``policy`` decides when deltas fold into a new sealed base.

    Returns the per-shard results, plus the final store when
    ``return_store=True`` (hand it to ``dedup_against`` / ``JoinEngine`` /
    ``serve.JoinSession`` to keep streaming).
    """
    from repro.core.plan import JoinPlan
    from repro.store import CorpusStore

    plan = JoinPlan(driver="blocked", sim=JACCARD, tau=float(tau),
                    b=int(kw.get("b", 128)), block=int(kw.get("block", 4096)),
                    impl=kw.get("impl", "auto"),
                    compaction=kw.get("compaction", "device"))
    store = CorpusStore(corpus, JACCARD, float(tau), plan=plan, policy=policy)
    results: List[IncrementalDedupResult] = []
    for shard in shards:
        res = dedup_against(store, shard, tau, **kw)
        src = shard.source if isinstance(shard, PreparedCollection) else shard
        if len(res.keep):
            store.append(Collection(tokens=src.tokens[res.keep],
                                    lengths=src.lengths[res.keep]))
        results.append(res)
    return (results, store) if return_store else results


def dedup_documents_against(corpus_texts: Sequence[str],
                            new_texts: Sequence[str], tau: float = 0.8,
                            width: int = 5,
                            **kw) -> Tuple[List[str], IncrementalDedupResult]:
    """Document flavour of :func:`dedup_against` (shared shingle space —
    both sides are shingled in this call, so hashes are comparable)."""
    corpus = from_lists([shingle(t, width) for t in corpus_texts])
    new = from_lists([shingle(t, width) for t in new_texts])
    res = dedup_against(corpus, new, tau, **kw)
    return [new_texts[i] for i in res.keep], res
