"""Synthetic collection generators following the paper's methodology (§5).

The paper's UNIFORM and ZIPF collections are generated with Poisson set sizes
and uniform / Zipf token draws; AOL/DBLP/ENRON-like collections are matched on
the published statistics of Table 4 (set-size distribution family + number of
distinct tokens) since the originals are not redistributable here.

``with_duplicates`` plants near-duplicate clusters with a controlled Jaccard
level — used by join tests (ground truth guaranteed to be non-empty) and by
the dedup-pipeline example.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.collection import Collection, from_lists, preprocess


def _draw_sets(rng, n_sets: int, avg_size: float, n_tokens: int,
               dist: str, zipf_a: float = 1.2):
    sizes = np.maximum(rng.poisson(avg_size, size=n_sets), 1)
    sets = []
    for sz in sizes:
        if dist == "uniform":
            toks = rng.integers(0, n_tokens, size=2 * sz + 8)
        elif dist == "zipf":
            toks = (rng.zipf(zipf_a, size=4 * sz + 16) - 1) % n_tokens
        else:
            raise ValueError(dist)
        u = np.unique(toks)[:sz]
        if len(u) == 0:
            u = np.array([int(rng.integers(0, n_tokens))])
        sets.append(u.tolist())
    return sets


def uniform_collection(n_sets: int = 1000, avg_size: float = 10.0,
                       n_tokens: int = 220, seed: int = 0) -> Collection:
    """Paper's UNIFORM: Poisson sizes (avg ~10), 220 distinct tokens."""
    rng = np.random.default_rng(seed)
    return preprocess(from_lists(_draw_sets(rng, n_sets, avg_size, n_tokens, "uniform")))


def zipf_collection(n_sets: int = 1000, avg_size: float = 50.0,
                    n_tokens: int = 101_584, seed: int = 0) -> Collection:
    """Paper's ZIPF: Poisson sizes (avg ~50), Zipf-distributed tokens."""
    rng = np.random.default_rng(seed)
    return preprocess(from_lists(_draw_sets(rng, n_sets, avg_size, n_tokens, "zipf")))


def skewed_collection(n_sets: int = 1000, avg_size: float = 9.0,
                      n_tokens: int = 100_000, zipf_a: float = 1.5,
                      seed: int = 0) -> Collection:
    """Zipf-skewed token frequencies *without* the head-only truncation bias.

    ``zipf_collection`` keeps the first (smallest-valued == most frequent)
    tokens of each draw, which at small set sizes collapses every set onto
    the distribution head and yields a degenerate, near-all-duplicates
    collection.  Here each set keeps a *random* subset of its draw, so head
    tokens are shared across many sets (real skew for prefix indexes to
    cope with) while tail tokens keep sets distinct — the shape the
    indexed-vs-blocked comparisons use.
    """
    rng = np.random.default_rng(seed)
    sizes = np.maximum(rng.poisson(avg_size, size=n_sets), 1)
    sets = []
    for sz in sizes:
        u = np.unique((rng.zipf(zipf_a, size=4 * sz + 16) - 1) % n_tokens)
        sets.append(rng.permutation(u)[:sz].tolist())
    return preprocess(from_lists(sets))


def dblp_like_collection(n_sets: int = 1000, seed: int = 0) -> Collection:
    """DBLP-like: symmetric size distribution around ~106, 3801 tokens."""
    rng = np.random.default_rng(seed)
    sizes = np.clip(rng.normal(106, 25, size=n_sets), 8, 400).astype(int)
    sets = []
    for sz in sizes:
        toks = (rng.zipf(1.15, size=4 * sz + 16) - 1) % 3801
        u = np.unique(toks)[:sz]
        sets.append(u.tolist())
    return preprocess(from_lists(sets))


def with_duplicates(
    base: Collection,
    n_clusters: int = 20,
    cluster_size: int = 3,
    jaccard: float = 0.9,
    seed: int = 0,
) -> Collection:
    """Plant near-duplicate clusters at a target Jaccard into a collection."""
    rng = np.random.default_rng(seed)
    rows = base.as_lists()
    universe = max(max(r) for r in rows if r) + 1
    for _ in range(n_clusters):
        src = rows[int(rng.integers(0, len(rows)))]
        n = len(src)
        # |r ∩ s| / |r ∪ s| = j with |r| = |s| = n  =>  overlap = 2jn/(1+j)
        keep = max(int(round(2 * jaccard * n / (1 + jaccard))), 1)
        keep = min(keep, n)
        for _ in range(cluster_size - 1):
            kept = list(rng.choice(src, size=keep, replace=False))
            extra = [int(rng.integers(universe, universe + 10 * n))
                     for _ in range(n - keep)]
            rows.append(sorted(set(kept + extra)))
    return preprocess(from_lists(rows))
