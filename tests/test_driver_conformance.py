"""The single driver-conformance suite: EVERY registered join driver vs the
f64 oracle, from one parameterized sweep.

``repro.core.plan.DRIVERS`` is the driver *registry* and this suite derives
its coverage from it: an executor must exist here for every registered
driver (and vice versa — asserted by ``test_registry_fully_covered``), so a
future driver cannot ship without oracle coverage.  This file replaces the
per-driver copies of the sim×τ sweep that used to drift across
``test_join.py`` / ``test_rs_join.py`` / ``test_indexed_join.py``.

The shared grid: 4 similarity functions × τ ∈ {0.5, 0.6, 0.75, 0.8, 0.9,
0.95} (overlap rescales τ to an absolute count) × uniform / skewed /
dup-heavy collections × self-join and R×S.  The mesh drivers (``ring``,
``sharded-indexed``) run over all available devices — one in the default
tier-1 run, eight in the ``scripts/check.sh`` mesh gate
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — and the
``sharded-indexed`` executor additionally pins its pair set *and* summed
``JoinStats`` to the single-device ``indexed`` driver on every grid cell.

The funnel property tests at the bottom are the shared device-driver
invariant suite: ``candidates_generated >= candidates(after bitmap) >=
verified_true``, ratios in [0, 1], and permutation-invariance of the summed
funnel under probe batching.
"""

import functools
import zlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no pip index — seeded fallback
    from _propstrat import given, settings, strategies as st

from repro.core import cpu_algos, join, plan as plan_mod
from repro.core.collection import from_lists
from repro.core.engine import JoinEngine, prepare, prepared_bitmap_filter
from repro.core.plan import JoinPlan

TAUS = (0.5, 0.6, 0.75, 0.8, 0.9, 0.95)
SIMS = ("jaccard", "cosine", "dice", "overlap")
KINDS = ("uniform", "skewed", "dup_heavy")
MODES = ("self", "rs")

_PAD = 12   # fixed padded width -> one jit cache across the whole sweep
_B = 32
_BLOCK = 16


def _threshold(sim: str, tau: float) -> float:
    """Overlap takes an absolute count, not a ratio: rescale the shared τ
    grid onto {4..8} so every cell stays a non-trivial join."""
    return float(max(1, round(tau * 8))) if sim == "overlap" else tau


def _sets(kind: str, rng, n: int, universe: int = 110):
    if kind == "uniform":
        return [rng.choice(universe, size=rng.integers(1, 13),
                           replace=False).tolist() for _ in range(n)]
    if kind == "skewed":
        sets = []
        for _ in range(n):
            sz = int(rng.integers(1, 13))
            toks = np.unique(np.minimum(rng.zipf(1.3, size=3 * sz + 4),
                                        universe + 30))[:sz]
            sets.append(toks.tolist())
        return sets
    if kind == "dup_heavy":
        base = [rng.choice(universe, size=rng.integers(2, 13),
                           replace=False).tolist() for _ in range(max(n // 4, 1))]
        sets = []
        for _ in range(n):
            src = base[int(rng.integers(len(base)))]
            kept = [t for t in src if rng.random() > 0.15]
            sets.append(kept or src[:1])
        return sets
    raise KeyError(kind)


@functools.lru_cache(maxsize=None)
def _collections(kind: str, mode: str):
    """(col_r, col_s-or-None) for one grid cell family; R×S plants
    cross-collection duplicates so every cell joins non-trivially."""
    # crc32, not hash(): str hashing is salted per process, and the grid
    # must be identical across the tier-1 run and the 8-device mesh gate.
    rng = np.random.default_rng(zlib.crc32(f"{kind}:{mode}".encode()))
    sets_r = _sets(kind, rng, 36)
    # Planted exact + near duplicates: every family must join non-trivially
    # even at τ = 0.95 (asserted by test_grid_is_nontrivial).
    for k in range(0, 12, 3):
        sets_r[k + 1] = list(sets_r[k])
        if len(sets_r[k]) > 2:
            sets_r[k + 2] = list(sets_r[k][:-1])
    col_r = from_lists(sets_r, pad_to=_PAD)
    if mode == "self":
        return col_r, None
    sets_s = _sets(kind, rng, 24)
    for k in range(4):
        sets_s[k] = list(col_r.row(3 * k))
    return col_r, from_lists(sets_s, pad_to=_PAD)


@functools.lru_cache(maxsize=None)
def _prepared(kind: str, mode: str):
    col_r, col_s = _collections(kind, mode)
    return prepare(col_r), None if col_s is None else prepare(col_s)


@functools.lru_cache(maxsize=None)
def _oracle(sim: str, tau: float, kind: str, mode: str):
    col_r, col_s = _collections(kind, mode)
    return join.naive_join(col_r, col_s, sim, tau)


@functools.lru_cache(maxsize=1)
def _mesh():
    import jax

    from repro.launch.mesh import make_mesh
    return make_mesh((jax.device_count(),), ("data",))


# ---------------------------------------------------------------------------
# One executor per registered driver.  Each takes the grid cell and returns
# the driver's pair set (original indices, oracle ordering).
# ---------------------------------------------------------------------------

def _run_naive(sim, tau, kind, mode):
    col_r, col_s = _collections(kind, mode)
    return join.naive_join(col_r, col_s, sim, tau)


def _run_blocked(sim, tau, kind, mode):
    prep_r, prep_s = _prepared(kind, mode)
    return join.blocked_bitmap_join_prepared(
        prep_r, prep_s, sim=sim, tau=tau, b=_B, block=_BLOCK)


def _run_ring(sim, tau, kind, mode):
    prep_r, prep_s = _prepared(kind, mode)
    return join.ring_join_prepared(
        prep_r, prep_s, mesh=_mesh(), axis="data", sim=sim, tau=tau, b=_B)


@functools.lru_cache(maxsize=None)
def _indexed_result(sim, tau, kind, mode):
    """(pairs, stats) of the single-device indexed driver, cached: it is
    both a conformance subject and the sharded driver's reference."""
    from repro.index import indexed_join_prepared

    prep_r, prep_s = _prepared(kind, mode)
    return indexed_join_prepared(
        prep_r, prep_s, sim=sim, tau=tau, b=_B, probe_block=_BLOCK,
        return_stats=True)


def _run_indexed(sim, tau, kind, mode):
    return _indexed_result(sim, tau, kind, mode)[0]


def _run_sharded_indexed(sim, tau, kind, mode):
    """The acceptance bar for the sharded driver is stronger than oracle
    equality: its pair set AND summed per-shard JoinStats must be
    bit-identical to the single-device indexed driver on every cell."""
    from repro.distributed.sharded_index import sharded_indexed_join_prepared

    prep_r, prep_s = _prepared(kind, mode)
    pairs, stats = sharded_indexed_join_prepared(
        prep_r, prep_s, mesh=_mesh(), axis="data", sim=sim, tau=tau, b=_B,
        probe_block=_BLOCK, return_stats=True)
    ref_pairs, ref_stats = _indexed_result(sim, tau, kind, mode)
    assert np.array_equal(pairs, ref_pairs), (sim, tau, kind, mode)
    assert stats.to_dict() == ref_stats.to_dict(), (
        sim, tau, kind, mode, stats.to_dict(), ref_stats.to_dict())
    return pairs


def _cpu_executor(algo: str):
    def run(sim, tau, kind, mode):
        prep_r, prep_s = _prepared(kind, mode)
        bf = prepared_bitmap_filter(prep_r, prep_s, sim=sim, tau=tau, b=_B)
        stats = cpu_algos.AlgoStats()
        pairs = cpu_algos.ALGORITHMS[algo](prep_r, prep_s, sim, tau,
                                           bitmap=bf, stats=stats)
        assert stats.results == len(pairs)
        return pairs

    return run


EXECUTORS = {
    "naive": _run_naive,
    "blocked": _run_blocked,
    "ring": _run_ring,
    "indexed": _run_indexed,
    "sharded-indexed": _run_sharded_indexed,
    **{algo: _cpu_executor(algo) for algo in cpu_algos.ALGORITHMS},
}


def test_registry_fully_covered():
    """The registry contract: plan.DRIVERS and the conformance executors
    must match exactly — registering a driver without adding it here (or
    covering a driver that was never registered) fails the suite."""
    assert set(EXECUTORS) == set(plan_mod.DRIVERS), (
        sorted(set(EXECUTORS) ^ set(plan_mod.DRIVERS)))
    assert len(plan_mod.DRIVERS) >= 9


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("sim", SIMS)
@pytest.mark.parametrize("driver", sorted(EXECUTORS))
def test_driver_matches_oracle(driver, sim, mode):
    """One driver × one sim × one join mode, swept over the full τ × shape
    grid (18 cells per test): the driver's pair set must equal the f64
    oracle's exactly on every cell."""
    for tau in TAUS:
        th = _threshold(sim, tau)
        for kind in KINDS:
            oracle = _oracle(sim, th, kind, mode)
            got = EXECUTORS[driver](sim, th, kind, mode)
            assert np.array_equal(got, oracle), (
                driver, sim, th, kind, mode, len(got), len(oracle))


def test_grid_is_nontrivial():
    """Guard the guard: a sweep of all-empty joins would vacuously pass, so
    every (sim, kind, mode) family must produce pairs somewhere on the τ
    grid."""
    for sim in SIMS:
        for kind in KINDS:
            for mode in MODES:
                assert any(
                    len(_oracle(sim, _threshold(sim, tau), kind, mode))
                    for tau in TAUS), (sim, kind, mode)


# ---------------------------------------------------------------------------
# Shared device-driver funnel invariants (property-driven)
# ---------------------------------------------------------------------------

FUNNEL_FIELDS = ("total_pairs", "candidates", "verified_true",
                 "candidates_generated", "postings_expanded")
FUNNEL_DRIVERS = ("naive", "blocked", "ring", "indexed", "sharded-indexed")
FUNNEL_SIMTAUS = (("jaccard", 0.7), ("cosine", 0.8), ("dice", 0.6),
                  ("overlap", 4.0))


def _check_funnel(stats):
    assert (stats.verified_true <= stats.candidates
            <= stats.candidates_generated), stats
    assert stats.candidates <= stats.total_pairs, stats
    assert 0.0 <= stats.filter_ratio <= 1.0, stats
    assert 0.0 <= stats.precision <= 1.0, stats
    assert stats.blocks_skipped <= stats.blocks_total, stats
    assert stats.overflow_blocks >= 0, stats


def _funnel_engine(driver, sim, tau, corpus):
    plan = JoinPlan(driver=driver, sim=sim, tau=tau, b=_B, block=8)
    mesh = _mesh() if driver in ("ring", "sharded-indexed") else None
    return JoinEngine(corpus, sim, tau, plan=plan, mesh=mesh,
                      axis=None if mesh is None else "data")


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), driver=st.sampled_from(FUNNEL_DRIVERS),
       simtau=st.sampled_from(FUNNEL_SIMTAUS))
def test_funnel_invariants_and_batch_permutation(seed, driver, simtau):
    """Every device driver, probed through the engine in batches: per-batch
    funnel invariants hold, and the summed funnel counters are invariant
    under permuting which probe rows land in which batch — the stats are a
    property of the (R, S) multiset, not of the batching."""
    sim, tau = simtau
    rng = np.random.default_rng(seed)
    corpus_sets = _sets("dup_heavy", rng, 40)
    probe_sets = _sets("uniform", rng, 24, universe=110)
    for k in range(5):  # planted cross-collection duplicates
        probe_sets[k] = corpus_sets[2 * k]
    corpus = from_lists(corpus_sets, pad_to=_PAD)

    def summed(order):
        engine = _funnel_engine(driver, sim, tau, corpus)
        totals = dict.fromkeys(FUNNEL_FIELDS, 0)
        for i in range(0, len(order), 8):
            batch = from_lists([probe_sets[j] for j in order[i:i + 8]],
                               pad_to=_PAD)
            _pairs, stats = engine.probe(batch)
            _check_funnel(stats)
            for f in FUNNEL_FIELDS:
                totals[f] += getattr(stats, f)
        return totals

    identity = list(range(len(probe_sets)))
    shuffled = list(rng.permutation(len(probe_sets)))
    assert summed(identity) == summed(shuffled), (driver, sim, tau, seed)


# ---------------------------------------------------------------------------
# Store conformance: every registered driver must declare — and honor — its
# behavior under the appendable store's segment-union join.
# ---------------------------------------------------------------------------

def test_store_support_fully_declared():
    """The mutation-over-time registry contract: a driver cannot ship
    without declaring what the store's decomposition preserves for it
    ("exact" = pairs + summed funnel, "pairs" = pairs only)."""
    assert set(plan_mod.STORE_SUPPORT) == set(plan_mod.DRIVERS), (
        sorted(set(plan_mod.STORE_SUPPORT) ^ set(plan_mod.DRIVERS)))
    assert set(plan_mod.STORE_SUPPORT.values()) <= {"exact", "pairs"}
    # The paper-path device drivers all owe the stronger contract.
    for d in ("naive", "blocked", "ring", "indexed", "sharded-indexed"):
        assert plan_mod.STORE_SUPPORT[d] == "exact", d


def _store_cell(kind):
    """(base, deltas, probe batch) for the store sweep: one dup-heavy cell
    sliced so duplicate clusters genuinely span the segment boundaries."""
    from repro.core.collection import Collection

    col_r, _ = _collections(kind, "self")
    _, col_s = _collections(kind, "rs")

    def rows(a, b):
        return Collection(tokens=col_r.tokens[a:b],
                          lengths=col_r.lengths[a:b])

    return rows(0, 24), [rows(24, 30), rows(30, 36)], col_s


@pytest.mark.parametrize("driver", sorted(plan_mod.DRIVERS))
def test_driver_store_conformance(driver):
    """One driver across a scripted append/probe/compact schedule × sims ×
    τ: at every compaction state the store's segment-union join must match
    a from-scratch rebuild under the same plan — pairs for every driver,
    summed funnel counters too for the "exact" tier (probe: all five
    fields; self-join: all but the direction-dependent
    ``postings_expanded``)."""
    from repro.store import (FUNNEL_SUM_FIELDS, PROBE_SUM_FIELDS,
                             CompactionPolicy, CorpusStore)

    level = plan_mod.STORE_SUPPORT[driver]
    mesh = _mesh() if driver in ("ring", "sharded-indexed") else None
    axis = "data" if mesh is not None else None
    base, deltas, batch = _store_cell("dup_heavy")
    for sim in ("jaccard", "cosine"):
        for tau in (0.6, 0.75, 0.9):
            plan = JoinPlan(driver=driver, sim=sim, tau=tau, b=_B,
                            block=_BLOCK)
            store = CorpusStore(base, sim, tau, plan=plan, mesh=mesh,
                                axis=axis, policy=CompactionPolicy.never())

            def check(label):
                oracle = JoinEngine(prepare(store.collection()), sim, tau,
                                    plan=plan, mesh=mesh, axis=axis)
                pairs, stats = store.probe(batch)
                op, ostats = oracle.probe(batch)
                assert np.array_equal(pairs, op), (driver, sim, tau, label)
                sp, sstats = store.self_join(return_stats=True)
                osp, osstats = oracle.self_join(return_stats=True)
                assert np.array_equal(sp, osp), (driver, sim, tau, label)
                if level == "exact":
                    for f in PROBE_SUM_FIELDS:
                        assert getattr(stats, f) == getattr(ostats, f), (
                            driver, sim, tau, label, f)
                    for f in FUNNEL_SUM_FIELDS:
                        assert getattr(sstats, f) == getattr(osstats, f), (
                            driver, sim, tau, label, f)

            for delta in deltas:
                store.append(delta)
                check("delta")
            assert store.builds()["sort"] == 1   # appends never rebuilt R
            store.compact()
            check("compacted")
