"""Oracle-differential property harness for the compaction/escalation paths.

Exactness is the product: every join driver — host compaction, the
device-resident compaction path (prepass-sized and forced-tiny capacities
that overflow into the dense escalation) — must return *exactly* the
``naive_join`` oracle's pair set for every similarity function, threshold
and collection shape.  The harness samples sim ∈ {jaccard, cosine, dice,
overlap}, τ across [0.5, 0.95] (absolute thresholds for overlap), and
uniform / skewed / duplicate-heavy collections, and additionally asserts the
``JoinStats`` invariants and the host-vs-device bit-for-bit counter match.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no pip index — seeded fallback
    from _propstrat import given, settings, strategies as st

from repro.core import join
from repro.core.collection import from_lists

# sim × τ grid spanning the acceptance range; overlap takes absolute counts.
SIM_TAUS = ([(s, t) for s in ("jaccard", "cosine", "dice")
             for t in (0.5, 0.7, 0.85, 0.95)]
            + [("overlap", 2.0), ("overlap", 5.0)])

_PAD = 16  # fixed padded width -> one jit cache across examples
KINDS = ("uniform", "skewed", "dup_heavy")


def _collection(kind: str, seed: int, n: int = 48):
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        sets = [rng.choice(110, size=rng.integers(1, 13), replace=False).tolist()
                for _ in range(n)]
    elif kind == "skewed":
        # Zipf-distributed token draws: a few tokens appear in most sets.
        sets = []
        for _ in range(n):
            sz = int(rng.integers(1, 13))
            toks = np.unique(np.minimum(rng.zipf(1.3, size=3 * sz + 4), 140))[:sz]
            sets.append(toks.tolist())
    elif kind == "dup_heavy":
        # Near-copies of a small base pool: dense candidate tiles, many true
        # pairs — the capacity-overflow stressor.
        base = [rng.choice(110, size=rng.integers(2, 13), replace=False).tolist()
                for _ in range(max(n // 4, 1))]
        sets = []
        for _ in range(n):
            src = base[int(rng.integers(len(base)))]
            kept = [t for t in src if rng.random() > 0.15]
            sets.append(kept or src[:1])
    else:
        raise KeyError(kind)
    return from_lists(sets, pad_to=_PAD)


def _check_invariants(stats: join.JoinStats):
    assert 0.0 <= stats.filter_ratio <= 1.0, stats
    assert 0.0 <= stats.precision <= 1.0, stats
    assert stats.verified_true <= stats.candidates <= stats.total_pairs, stats
    assert stats.blocks_skipped <= stats.blocks_total, stats
    assert stats.overflow_blocks >= 0, stats


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), simtau=st.sampled_from(SIM_TAUS),
       kind=st.sampled_from(KINDS))
def test_device_resident_join_matches_oracle(seed, simtau, kind):
    """Self-join: host path, device-resident path and the oracle all agree;
    JoinStats counters match bit-for-bit between the two compaction modes."""
    sim, tau = simtau
    col = _collection(kind, seed)
    oracle = join.naive_join(col, sim, tau)
    host, hstats = join.blocked_bitmap_join(
        col, sim, tau, b=32, block=16, return_stats=True)
    dev, dstats = join.blocked_bitmap_join(
        col, sim, tau, b=32, block=16, compaction="device", return_stats=True)
    assert np.array_equal(oracle, host), (sim, tau, kind, len(oracle), len(host))
    assert np.array_equal(oracle, dev), (sim, tau, kind, len(oracle), len(dev))
    assert hstats == dstats, (hstats, dstats)
    assert dstats.overflow_blocks == 0  # prepass-sized capacity never overflows
    _check_invariants(dstats)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), simtau=st.sampled_from(SIM_TAUS),
       cap=st.sampled_from((1, 2, 4, 8)))
def test_forced_overflow_escalation_matches_oracle(seed, simtau, cap):
    """Deliberately tiny capacities: overflowing block pairs must be flagged
    and escalated to the dense path without losing a single pair."""
    sim, tau = simtau
    col = _collection("dup_heavy", seed)
    oracle = join.naive_join(col, sim, tau)
    got, stats = join.blocked_bitmap_join(
        col, sim, tau, b=32, block=16, compaction="device", capacity=cap,
        return_stats=True)
    assert np.array_equal(oracle, got), (sim, tau, cap, len(oracle), len(got))
    _check_invariants(stats)
    # Pigeonhole: more candidates than cap × surviving block pairs means at
    # least one block pair overflowed — the flag it claims must be set.
    surviving = stats.blocks_total - stats.blocks_skipped
    if stats.candidates > cap * surviving:
        assert stats.overflow_blocks > 0, stats


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), simtau=st.sampled_from(SIM_TAUS),
       cap=st.sampled_from((None, 4)))
def test_rs_join_device_resident_matches_oracle(seed, simtau, cap):
    """R×S two-collection joins through the resident path (both prepass-sized
    and forced-overflow capacity)."""
    sim, tau = simtau
    rng = np.random.default_rng(seed)
    col_r = _collection("uniform", seed, n=48)
    sets_s = [rng.choice(110, size=rng.integers(1, 13), replace=False).tolist()
              for _ in range(32)]
    for k in range(4):  # cross-collection duplicates -> non-trivial joins
        sets_s[k] = list(col_r.row(3 * k))
    col_s = from_lists(sets_s, pad_to=_PAD)
    oracle = join.naive_join(col_r, col_s, sim, tau)
    got, stats = join.blocked_bitmap_join(
        col_r, col_s, sim, tau, b=32, block=16, compaction="device",
        capacity=cap, return_stats=True)
    assert np.array_equal(oracle, got), (sim, tau, cap, len(oracle), len(got))
    _check_invariants(stats)


def test_device_path_never_compacts_on_host(monkeypatch):
    """The resident path must not touch the dense host-compaction route
    (``_dense_block_verify`` is the only place a dense verdict tile crosses
    to the host) unless a tile overflows its capacity."""
    col = _collection("uniform", seed=0)
    oracle = join.naive_join(col, "jaccard", 0.6)

    def boom(*a, **kw):
        raise AssertionError("dense host compaction used on the resident path")

    monkeypatch.setattr(join, "_dense_block_verify", boom)
    got = join.blocked_bitmap_join(
        col, "jaccard", 0.6, b=32, block=16, compaction="device")
    assert np.array_equal(oracle, got)
    # ... the host path, by contrast, lives on it:
    with pytest.raises(AssertionError, match="dense host compaction"):
        join.blocked_bitmap_join(col, "jaccard", 0.6, b=32, block=16)


def test_joinstats_json_roundtrip():
    _, stats = join.blocked_bitmap_join(
        _collection("dup_heavy", seed=3), "jaccard", 0.7, b=32, block=16,
        compaction="device", return_stats=True)
    d = stats.to_dict()
    import json
    parsed = json.loads(json.dumps(d))
    assert parsed["candidates"] == stats.candidates
    assert parsed["filter_ratio"] == pytest.approx(stats.filter_ratio)
    assert set(parsed) >= {"total_pairs", "candidates", "verified_true",
                           "overflow_blocks", "filter_ratio", "precision"}


def test_invalid_compaction_mode_rejected():
    with pytest.raises(ValueError, match="compaction"):
        join.blocked_bitmap_join(_collection("uniform", 1), "jaccard", 0.8,
                                 compaction="gpu")


@pytest.mark.parametrize("sim,tau", [("jaccard", 0.8), ("jaccard", 0.9),
                                     ("dice", 0.8), ("cosine", 0.75)])
def test_exactly_at_threshold_pairs_agree_across_drivers(sim, tau):
    """Subset pairs whose similarity sits exactly on (or within float ulps
    of) tau: every driver must return the float64 oracle's verdict.

    Regression for the f32-acceptance bug where r=range(28) ⊂ s=range(35)
    at Jaccard 0.8 got three different answers from naive / blocked /
    indexed (device float32 re-derivation of the Table 1 threshold flips
    membership on boundaries; acceptance now goes through the integer
    ``bounds.min_overlap_table``)."""
    from repro.index import indexed_bitmap_join

    sets = []
    for n in range(2, 40):
        base = list(range(1000 + n * 60, 1000 + n * 60 + n))
        sets.append(base)
        for extra in (1, 2, 3, 7):
            sets.append(base + list(range(7000 + n * 60, 7000 + n * 60 + extra)))
    col = from_lists(sets)
    oracle = join.naive_join(col, sim, tau)
    host = join.blocked_bitmap_join(col, sim, tau, b=32, block=32)
    dev = join.blocked_bitmap_join(col, sim, tau, b=32, block=32,
                                   compaction="device")
    idx = indexed_bitmap_join(col, sim, tau, b=32, probe_block=32)
    assert np.array_equal(oracle, host)
    assert np.array_equal(oracle, dev)
    assert np.array_equal(oracle, idx)
