"""Seeded-random stand-in for ``hypothesis`` when it is not installed.

Implements just the surface the property tests use — ``given``, ``settings``
and the ``integers`` / ``floats`` / ``sampled_from`` / ``lists`` strategies
(plus ``.filter``) — by drawing ``max_examples`` samples from a deterministic
per-test ``numpy`` generator.  No shrinking, no example database: failures
print the sampled arguments so they can be replayed by hand.
"""

from __future__ import annotations

import functools
import inspect
import zlib
from typing import Any, Callable, Dict


class _Strategy:
    def __init__(self, sample: Callable):
        self._sample = sample

    def sample(self, rng):
        return self._sample(rng)

    def filter(self, pred: Callable[[Any], bool]) -> "_Strategy":
        def sample(rng):
            for _ in range(10_000):
                v = self._sample(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate rejected 10k samples")

        return _Strategy(sample)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        return _Strategy(
            lambda rng: [elements.sample(rng)
                         for _ in range(int(rng.integers(min_size, max_size + 1)))])


def given(**named: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kw):
            import numpy as np

            n = getattr(wrapper, "_max_examples", 20)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                sampled: Dict[str, Any] = {k: s.sample(rng) for k, s in named.items()}
                try:
                    fn(*args, **sampled, **kw)
                except Exception:
                    print(f"\n{fn.__name__} failed with sampled args: {sampled!r}")
                    raise

        wrapper._max_examples = 20
        # pytest resolves undeclared parameters as fixtures: hide the sampled
        # ones from the collected signature (hypothesis does the same).
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items() if name not in named]
        wrapper.__signature__ = sig.replace(parameters=params)
        del wrapper.__wrapped__
        return wrapper

    return deco


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco
