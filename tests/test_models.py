"""Per-architecture smoke tests (reduced same-family configs, CPU):
forward/train step with shape + finiteness asserts, decode parity with the
teacher-forced forward pass, triangle-schedule equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.shapes import SHAPES, demo_batch, input_specs, shape_applicable
from repro.models import DecodeEngine, Model

B, S = 2, 32


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in configs.ARCHS:
        cfg = configs.get_reduced(name)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        out[name] = (cfg, model, params)
    return out


@pytest.mark.parametrize("name", configs.ARCHS)
def test_forward_and_train_step(built, name):
    cfg, model, params = built[name]
    batch = demo_batch(cfg, B, S)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in flat) ** 0.5
    assert gnorm > 0


@pytest.mark.parametrize("name", configs.ARCHS)
def test_decode_matches_forward(built, name):
    """Teacher-forced decode must reproduce the forward logits step by step —
    the strongest cache-correctness check we have."""
    cfg, model, params = built[name]
    batch = demo_batch(cfg, B, S)
    eng = DecodeEngine(model)
    ref_logits, _ = jax.jit(model.forward)(params, batch)
    prefix = S // 2
    if cfg.frame_inputs:
        pre = {"frame_embeds": batch["frame_embeds"][:, :prefix]}
    else:
        pre = {k: v[:, :prefix] for k, v in batch.items() if k != "labels"}
        if "image_embeds" in batch:
            pre["image_embeds"] = batch["image_embeds"]
    logits, cache = jax.jit(lambda p, b: eng.prefill(p, b, max_len=S))(params, pre)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(ref_logits[:, :prefix], np.float32),
        rtol=2e-2, atol=2e-2)
    step = jax.jit(eng.decode_step)
    for t in range(prefix, S):
        if cfg.frame_inputs:
            sb = {"frame_embeds": batch["frame_embeds"][:, t:t + 1]}
        else:
            sb = {"tokens": batch["tokens"][:, t:t + 1]}
        logit_t, cache = step(params, cache, sb)
        np.testing.assert_allclose(
            np.asarray(logit_t[:, 0], np.float32),
            np.asarray(ref_logits[:, t], np.float32),
            rtol=6e-2, atol=6e-2, err_msg=f"{name} step {t}")


def test_triangle_schedule_equivalent(built):
    cfg, model, params = built["qwen3-8b"]
    batch = demo_batch(cfg, B, S)
    l0, _ = jax.jit(lambda p, b: model.forward(p, b, triangle=False))(params, batch)
    l1, _ = jax.jit(lambda p, b: model.forward(p, b, triangle=True))(params, batch)
    np.testing.assert_allclose(np.asarray(l0, np.float32), np.asarray(l1, np.float32),
                               rtol=1e-3, atol=1e-3)


def test_shape_applicability_matrix():
    """32 applicable LM cells + 8 documented skips = 40 assigned cells."""
    total, skips = 0, 0
    for name in configs.ARCHS:
        cfg = configs.get(name)
        for shape in SHAPES:
            total += 1
            if not shape_applicable(cfg, shape):
                skips += 1
                assert shape == "long_500k" and not cfg.subquadratic
    assert total == 40 and skips == 8


@pytest.mark.parametrize("name", configs.ARCHS)
def test_input_specs_complete(name):
    cfg = configs.get(name)
    for shape in SHAPES:
        if not shape_applicable(cfg, shape):
            continue
        specs = input_specs(cfg, shape)
        sp = SHAPES[shape]
        lead = specs["frame_embeds" if cfg.frame_inputs else "tokens"].shape
        assert lead[0] == sp.global_batch
        if sp.kind == "train":
            assert "labels" in specs
            assert lead[1] == sp.seq_len
        if sp.kind == "decode":
            assert lead[1] == 1


def test_full_config_param_counts():
    """Full configs must land near published sizes (assignment table)."""
    expect = {
        "smollm-135m": 0.135, "qwen3-8b": 8.2, "minitron-8b": 9.9,
        "internlm2-20b": 19.9, "zamba2-7b": 6.6, "phi3.5-moe-42b-a6.6b": 41.9,
        "arctic-480b": 477, "mamba2-2.7b": 2.7, "llama-3.2-vision-11b": 9.8,
        "musicgen-medium": 1.8,
    }
    for name, ref in expect.items():
        n = Model(configs.get(name)).num_params() / 1e9
        assert abs(n - ref) / ref < 0.08, (name, n, ref)
