"""R×S two-collection join specifics: calling conventions, empty inputs,
length-range early-outs and the BitmapFilter integration.  The full sim × τ
oracle sweep (blocked + every CPU algorithm) that used to drift here is now
owned by the single conformance suite (``tests/test_driver_conformance.py``)."""

import numpy as np
import pytest

from repro.core import cpu_algos, join
from repro.core.collection import from_lists, preprocess_rs
from repro.core.filters import BitmapFilter

ALGOS = list(cpu_algos.ALGORITHMS)


def _rs_collections(seed, n_r=60, n_s=45, universe=90, max_len=14, plant=4):
    rng = np.random.default_rng(seed)
    sets_r = [rng.choice(universe, size=rng.integers(1, max_len),
                         replace=False).tolist() for _ in range(n_r)]
    sets_s = [rng.choice(universe, size=rng.integers(1, max_len),
                         replace=False).tolist() for _ in range(n_s)]
    for k in range(plant):  # cross-collection duplicates -> non-empty joins
        sets_s[k] = sets_r[2 * k]
    return preprocess_rs(from_lists(sets_r), from_lists(sets_s))


@pytest.fixture(scope="module")
def rs_pair():
    return _rs_collections(seed=101)


@pytest.mark.parametrize("sim,tau", [("jaccard", 0.7), ("cosine", 0.8),
                                     ("dice", 0.6), ("overlap", 4.0)])
@pytest.mark.parametrize("algo", ALGOS)
def test_cpu_algos_rs_plain_equal_oracle(rs_pair, algo, sim, tau):
    """No-bitmap CPU path, one τ per similarity — the conformance sweep
    always plugs a bitmap in, so the bare prefix-filter route (including
    overlap's absolute, non-ratio threshold) is pinned here."""
    col_r, col_s = rs_pair
    oracle = join.naive_join(col_r, col_s, sim, tau)
    assert len(oracle) > 0, (sim, tau)
    got = cpu_algos.ALGORITHMS[algo](col_r, col_s, sim, tau)
    assert np.array_equal(oracle, got), (algo, sim, tau, len(oracle), len(got))


@pytest.mark.parametrize("algo", ALGOS)
def test_cpu_algos_rs_with_bitmap_exact(rs_pair, algo):
    col_r, col_s = rs_pair
    sim, tau = "jaccard", 0.7
    oracle = join.naive_join(col_r, col_s, sim, tau)
    bf = BitmapFilter.build_rs(col_r.tokens, col_r.lengths,
                               col_s.tokens, col_s.lengths, sim, tau, b=64)
    stats = cpu_algos.AlgoStats()
    got = cpu_algos.ALGORITHMS[algo](col_r, col_s, sim, tau,
                                     bitmap=bf, stats=stats)
    assert np.array_equal(oracle, got), algo
    assert stats.results == len(oracle)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_rs_property_random_collections(seed):
    col_r, col_s = _rs_collections(seed=seed, n_r=40, n_s=30)
    for sim, tau in [("jaccard", 0.5), ("cosine", 0.8), ("dice", 0.95)]:
        oracle = join.naive_join(col_r, col_s, sim, tau)
        got = join.blocked_bitmap_join(col_r, col_s, sim, tau, b=32, block=16)
        assert np.array_equal(oracle, got), (seed, sim, tau)


def test_rs_planted_duplicates_found(rs_pair):
    col_r, col_s = rs_pair
    pairs = join.blocked_bitmap_join(col_r, col_s, "jaccard", 0.95)
    assert len(pairs) >= 4  # the planted exact duplicates survive any tau


def test_empty_r():
    _, col_s = _rs_collections(seed=7)
    empty = from_lists([])
    assert join.naive_join(empty, col_s, "jaccard", 0.8).shape == (0, 2)
    assert join.blocked_bitmap_join(empty, col_s, "jaccard", 0.8).shape == (0, 2)
    for algo in ALGOS:
        assert cpu_algos.ALGORITHMS[algo](empty, col_s, "jaccard", 0.8).shape == (0, 2)


def test_empty_s():
    col_r, _ = _rs_collections(seed=8)
    empty = from_lists([])
    assert join.naive_join(col_r, empty, "jaccard", 0.8).shape == (0, 2)
    assert join.blocked_bitmap_join(col_r, empty, "jaccard", 0.8).shape == (0, 2)
    for algo in ALGOS:
        assert cpu_algos.ALGORITHMS[algo](col_r, empty, "jaccard", 0.8).shape == (0, 2)


def test_disjoint_length_ranges_early_out():
    """R is all short, S all long: the block walk must prune everything."""
    short = from_lists([[1, 2], [3, 4], [2, 5], [1, 6]])
    long_ = from_lists([list(range(i, i + 40)) for i in range(6)])
    got, stats = join.blocked_bitmap_join(
        short, long_, "jaccard", 0.8, block=2, return_stats=True)
    assert got.shape == (0, 2)
    assert stats.blocks_skipped > 0
    assert stats.blocks_skipped <= stats.blocks_total
    assert np.array_equal(got, join.naive_join(short, long_, "jaccard", 0.8))
    for algo in ALGOS:
        assert cpu_algos.ALGORITHMS[algo](short, long_, "jaccard", 0.8).shape == (0, 2)


def test_legacy_positional_self_join_convention(rs_pair):
    """(col, sim, tau) positional calls still mean a self-join."""
    col_r, _ = rs_pair
    a = join.naive_join(col_r, "jaccard", 0.7)
    b = join.naive_join(col_r, sim="jaccard", tau=0.7)
    assert np.array_equal(a, b)
    c = join.blocked_bitmap_join(col_r, "jaccard", 0.7)
    d = join.blocked_bitmap_join(col_r, sim="jaccard", tau=0.7)
    assert np.array_equal(c, d)
    assert np.array_equal(a, c)


def test_rs_join_is_directional(rs_pair):
    """R×S output is (r_index, s_index): swapping collections transposes it."""
    col_r, col_s = rs_pair
    ab = join.blocked_bitmap_join(col_r, col_s, "jaccard", 0.8)
    ba = join.blocked_bitmap_join(col_s, col_r, "jaccard", 0.8)
    assert np.array_equal(
        ab, ba[:, ::-1][np.lexsort((ba[:, 0], ba[:, 1]))])


def test_incremental_dedup_against_corpus():
    from repro.data.dedup import dedup_against
    col_r, col_s = _rs_collections(seed=9, plant=5)
    res = dedup_against(col_r, col_s, tau=0.95, b=64, block=32)
    assert len(res.drop_vs_corpus) >= 5        # the planted duplicates
    assert 0.0 <= res.stats_rs.filter_ratio <= 1.0
    assert (np.sort(np.concatenate([res.keep, res.drop_vs_corpus,
                                    res.drop_within]))
            == np.arange(col_s.num_sets)).all()
