"""Flash attention custom-VJP vs dense-softmax oracle (values + grads)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.layers import flash_attention


def dense_attn(q, k, v, causal=True):
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    qh = q.reshape(b, sq, kv, g, d)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qh, k).astype(jnp.float32) * d ** -0.5
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(b, sq, h, d)


CASES = [
    (2, 16, 16, 4, 2, 8, True, 4, 4),
    (1, 32, 32, 6, 3, 16, True, 8, 16),
    (2, 16, 24, 4, 4, 8, False, 4, 8),
    (1, 64, 64, 2, 1, 8, True, 16, 16),
    (1, 24, 40, 8, 2, 4, False, 8, 8),   # non-pow2 kv length (image tokens)
]


@pytest.mark.parametrize("b,sq,sk,h,kv,d,causal,qc,kc", CASES)
def test_flash_matches_dense(b, sq, sk, h, kv, d, causal, qc, kc):
    rng = np.random.default_rng(b + sq + h)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, kv, d)), jnp.float32)
    o1 = flash_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    o2 = dense_attn(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,sq,sk,h,kv,d,causal,qc,kc", CASES[:4])
def test_flash_grads_match_dense(b, sq, sk, h, kv, d, causal, qc, kc):
    rng = np.random.default_rng(17 + sq)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, kv, d)), jnp.float32)
    co = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    f = lambda *a: jnp.sum(flash_attention(
        *a, causal=causal, q_chunk=qc, kv_chunk=kc) * co)
    fd = lambda *a: jnp.sum(dense_attn(*a, causal) * co)
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(fd, argnums=(0, 1, 2))(q, k, v)
    for a, bb, nm in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=3e-4, atol=3e-4, err_msg=nm)


def test_triangle_schedule_identical():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 32, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 32, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 32, 2, 8)), jnp.float32)
    o1 = flash_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    o2 = flash_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8,
                         triangle_schedule=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6, atol=1e-6)
    g1 = jax.grad(lambda x: jnp.sum(flash_attention(
        x, k, v, causal=True, q_chunk=8, kv_chunk=8) ** 2))(q)
    g2 = jax.grad(lambda x: jnp.sum(flash_attention(
        x, k, v, causal=True, q_chunk=8, kv_chunk=8, triangle_schedule=True) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5, atol=1e-5)
