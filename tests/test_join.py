"""Blocked-join specifics: bitmap methods, cutoff toggles, stats sanity and
the dedup pipeline.  The sim × τ oracle sweep that used to live here is now
owned by the single conformance suite (``tests/test_driver_conformance.py``),
which runs it for every registered driver from one grid."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no pip index — seeded fallback
    from _propstrat import given, settings, strategies as st

from repro.core import join
from repro.core.collection import from_lists, preprocess
from repro.core.constants import BITMAP_METHODS
from repro.data.collections import uniform_collection, with_duplicates
from repro.data.dedup import dedup_collection


@pytest.mark.parametrize("method", BITMAP_METHODS)
def test_join_exact_for_every_method(tiny_collection, method):
    oracle = join.naive_join(tiny_collection, "jaccard", 0.6)
    got = join.blocked_bitmap_join(
        tiny_collection, "jaccard", 0.6, b=32, method=method, block=32)
    assert np.array_equal(oracle, got), method


def test_join_without_bitmap_matches(tiny_collection):
    oracle = join.naive_join(tiny_collection, "jaccard", 0.7)
    got = join.blocked_bitmap_join(tiny_collection, "jaccard", 0.7,
                                   use_bitmap=False, block=32)
    assert np.array_equal(oracle, got)


def test_cutoff_disabled_vs_enabled(small_collection):
    a = join.blocked_bitmap_join(small_collection, "jaccard", 0.8, use_cutoff=True)
    b = join.blocked_bitmap_join(small_collection, "jaccard", 0.8, use_cutoff=False)
    assert np.array_equal(a, b)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), tau=st.sampled_from([0.5, 0.7, 0.9]))
def test_join_property_random_collections(seed, tau):
    rng = np.random.default_rng(seed)
    sets = [rng.choice(60, size=rng.integers(1, 12), replace=False).tolist()
            for _ in range(40)]
    # plant one duplicate pair so the join is non-trivially non-empty
    sets.append(sets[0])
    col = preprocess(from_lists(sets))
    oracle = join.naive_join(col, "jaccard", tau)
    got = join.blocked_bitmap_join(col, "jaccard", tau, b=32, block=16)
    assert np.array_equal(oracle, got)
    assert len(oracle) >= 1  # the planted duplicate


def test_filter_ratio_high_at_high_threshold(small_collection):
    _, stats = join.blocked_bitmap_join(
        small_collection, "jaccard", 0.9, b=64, return_stats=True)
    # Paper Table 9: >=99% at tau=0.9 for UNIFORM-like collections.
    assert stats.filter_ratio > 0.95, stats


def test_dedup_collapses_planted_clusters():
    base = uniform_collection(n_sets=120, avg_size=12, n_tokens=400, seed=5)
    col = with_duplicates(base, n_clusters=8, cluster_size=3, jaccard=0.92, seed=6)
    res = dedup_collection(col, tau=0.8, b=64, block=64)
    assert len(res.pairs) >= 8           # at least the planted pairs
    assert len(res.drop) >= 8
    assert len(res.keep) + len(res.drop) == col.num_sets
    # dedup is idempotent: re-running on the kept set finds nothing at tau.
    from repro.core.collection import Collection
    kept = Collection(tokens=col.tokens[res.keep], lengths=col.lengths[res.keep])
    res2 = dedup_collection(kept, tau=0.8, b=64, block=64)
    assert len(res2.drop) == 0
