"""Appendable-corpus-store contract tests.

The load-bearing property is the **store exactness contract**: at every
compaction state reachable by any interleaving of append / probe / compact,
the store's segment-union join must be bit-identical — pairs AND summed
funnel ``JoinStats`` — to a from-scratch rebuild of the materialized
collection joined under the same plan.  The sweeps below script the
acceptance schedule (≥3 appends, ≥1 compaction, ≥2 sims × ≥3 τ), sample
random interleavings including empty / duplicate-heavy / forced-overflow
deltas, and assert the no-rebuild proof through the ``builds`` counters and
the serving layer's entrypoint trace counters.

Funnel scope (see ``repro.store.store``): probe stats compare on all five
funnel fields; self-join stats exclude ``postings_expanded`` (a full
self-join expands both directions of the symmetric length window, the
segmented cross joins expand one — the pair sets are still identical);
``blocks_total`` / ``blocks_skipped`` / ``overflow_blocks`` describe the
decomposition itself and are never contract-bound.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no pip index — seeded fallback
    from _propstrat import given, settings, strategies as st

from repro.core.collection import Collection, from_lists
from repro.core.engine import JoinEngine, prepare
from repro.core.plan import JoinPlan
from repro.data.dedup import dedup_against, dedup_shards
from repro.store import (
    FUNNEL_SUM_FIELDS,
    PROBE_SUM_FIELDS,
    CompactionPolicy,
    CorpusStore,
    StoreStats,
)

_PAD = 12   # fixed padded width -> one jit cache across the whole file
_B = 32
_BLOCK = 16


def _blocked_plan(sim="jaccard", tau=0.7, **kw):
    kw.setdefault("b", _B)
    kw.setdefault("block", _BLOCK)
    kw.setdefault("compaction", "host")
    return JoinPlan(driver="blocked", sim=sim, tau=tau, **kw)


def _col(n, seed, kind="uniform", universe=90):
    rng = np.random.default_rng(seed)
    if n == 0:
        return from_lists([], pad_to=_PAD)
    if kind == "dup_heavy":
        base = [rng.choice(universe, size=rng.integers(2, 11),
                           replace=False).tolist()
                for _ in range(max(n // 3, 1))]
        sets = []
        for _ in range(n):
            src = base[int(rng.integers(len(base)))]
            kept = [t for t in src if rng.random() > 0.15]
            sets.append(kept or src[:1])
        return from_lists(sets, pad_to=_PAD)
    return from_lists([rng.choice(universe, size=rng.integers(1, 11),
                                  replace=False).tolist() for _ in range(n)],
                      pad_to=_PAD)


def _oracle(store):
    """A from-scratch rebuild of the store's materialized collection under
    the store's own plan (+ mesh)."""
    return JoinEngine(prepare(store.collection()), store.sim, store.tau,
                      plan=store.plan, mesh=store.mesh, axis=store.axis)


def _assert_probe_identical(store, batch, *, stats=True):
    pairs, st_ = store.probe(batch)
    op, os_ = _oracle(store).probe(batch)
    assert np.array_equal(pairs, op), (len(pairs), len(op))
    if stats:
        for f in PROBE_SUM_FIELDS:
            assert getattr(st_, f) == getattr(os_, f), (
                f, getattr(st_, f), getattr(os_, f))
    return pairs


def _assert_self_join_identical(store, *, stats=True):
    pairs, st_ = store.self_join(return_stats=True)
    op, os_ = _oracle(store).self_join(return_stats=True)
    assert np.array_equal(pairs, op), (len(pairs), len(op))
    if stats:
        for f in FUNNEL_SUM_FIELDS:
            assert getattr(st_, f) == getattr(os_, f), (
                f, getattr(st_, f), getattr(os_, f))
    return pairs


# ---------------------------------------------------------------------------
# The acceptance schedule: ≥3 appends + ≥1 compaction, ≥2 sims × ≥3 τ,
# exactness at every step, base never rebuilt on append.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tau", (0.6, 0.7, 0.85))
@pytest.mark.parametrize("sim", ("jaccard", "cosine"))
def test_acceptance_schedule_exact_at_every_state(sim, tau):
    plan = _blocked_plan(sim, tau)
    store = CorpusStore(_col(30, 1, "dup_heavy"), sim, tau, plan=plan,
                        policy=CompactionPolicy.never())
    batch = _col(10, 99, "dup_heavy")

    # Probe once so the base's lazy artifacts (bitmap words) exist, then
    # freeze the counters: appends must not move them.
    _assert_probe_identical(store, batch)
    base_builds = store.builds()
    assert base_builds["sort"] == 1 and base_builds["bitmap"] == 1

    for i in range(3):
        store.append(_col(8, 10 + i, "dup_heavy"), compact=False)
        _assert_probe_identical(store, batch)
        _assert_self_join_identical(store)
        # The no-rebuild proof: the sealed base's counters are untouched.
        assert store.builds() == base_builds, (store.builds(), base_builds)
    assert store.stats().delta_count == 3 and store.compactions == 0

    assert store.compact()
    assert store.compactions == 1 and store.base_version == 1
    assert not store.deltas
    # Compaction (and only compaction) rebuilt: a fresh base, sort == 1.
    assert store.builds()["sort"] == 1
    assert store.stats().lifetime_builds["sort"] >= 5  # base + 3 deltas + new
    _assert_probe_identical(store, batch)
    _assert_self_join_identical(store)


def test_pairs_and_ids_stable_across_compaction():
    """Global ids are append-ordered, so the pair set for a fixed batch is
    literally the same array before and after any compaction."""
    store = CorpusStore(_col(24, 2, "dup_heavy"), "jaccard", 0.7,
                        plan=_blocked_plan(), policy=CompactionPolicy.never())
    offsets = [store.append(_col(7, 40 + i, "dup_heavy"),
                            compact=False).offset for i in range(3)]
    assert offsets == [24, 31, 38]
    batch = _col(9, 77, "dup_heavy")
    before = store.probe(batch, return_stats=False)
    store.compact()
    after = store.probe(batch, return_stats=False)
    assert np.array_equal(before, after)
    # ...and appending after a compaction picks up where the ids left off.
    assert store.append(_col(3, 90), compact=False).offset == 45


# ---------------------------------------------------------------------------
# Random interleavings (property sweep): append / probe / compact in any
# order, with empty and duplicate-heavy deltas.
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_random_interleavings_match_rebuild(seed):
    rng = np.random.default_rng(seed)
    store = CorpusStore(_col(20, seed, "dup_heavy"), "jaccard", 0.7,
                        plan=_blocked_plan(),
                        policy=CompactionPolicy(max_deltas=3, size_ratio=2.0))
    batch = _col(8, seed + 1, "dup_heavy")
    for step in range(6):
        op = rng.choice(["append", "append_empty", "append_dups", "probe",
                         "compact"])
        if op == "append":
            store.append(_col(int(rng.integers(1, 9)), seed + 10 + step))
        elif op == "append_empty":
            store.append(_col(0, 0))
        elif op == "append_dups":
            # Near-copies of rows the store already holds: the dup-heavy
            # delta must join against every earlier segment.
            src = store.collection()
            take = rng.integers(0, src.num_sets,
                                size=min(5, max(src.num_sets, 1)))
            sets = [src.row(int(i)).tolist() for i in take if
                    src.lengths[int(i)] > 0] or [[1, 2, 3]]
            store.append(from_lists(sets, pad_to=_PAD))
        elif op == "compact":
            store.compact()
        _assert_probe_identical(store, batch)
    _assert_self_join_identical(store)
    s = store.stats()
    assert s.base_rows + s.delta_rows == store.num_sets
    assert 0.0 <= s.delta_fraction <= 1.0


def test_forced_capacity_overflow_segments():
    """A forced tiny capacity makes segment joins overflow (dense-fallback
    escalation): pairs stay exact at every state; the summed funnel is
    legitimately decomposition-dependent, so only pairs are contract-bound
    here.  The overflow must actually fire for the test to mean anything."""
    # Overflow escalation lives on the device-compaction path: a block pair
    # whose candidate count exceeds the forced capacity re-runs densely.
    plan = _blocked_plan(tau=0.6, capacity=2, compaction="device")
    corpus = _col(24, 5, "dup_heavy")
    store = CorpusStore(corpus, "jaccard", 0.6, plan=plan,
                        policy=CompactionPolicy.never())
    # Exact corpus rows: every batch row matches its whole duplicate
    # cluster, so a 16×16 tile easily exceeds the forced 2-slot capacity.
    batch = from_lists([corpus.row(i).tolist() for i in range(10)],
                       pad_to=_PAD)
    tripped = 0
    for i in range(3):
        store.append(_col(8, 60 + i, "dup_heavy"), compact=False)
        pairs, stats = store.probe(batch)
        op = _oracle(store).probe(batch, return_stats=False)
        assert np.array_equal(pairs, op)
        tripped += stats.overflow_blocks
    assert tripped > 0
    store.compact()
    assert np.array_equal(store.probe(batch, return_stats=False),
                          _oracle(store).probe(batch, return_stats=False))


# ---------------------------------------------------------------------------
# Store mechanics: policies, empty stores, stats rollup, engine adoption.
# ---------------------------------------------------------------------------

def test_compaction_policy_triggers():
    assert CompactionPolicy(max_deltas=2).should_compact(100, [1, 1])
    assert not CompactionPolicy(max_deltas=3).should_compact(100, [1, 1])
    assert CompactionPolicy(size_ratio=0.5).should_compact(10, [6])
    assert not CompactionPolicy(size_ratio=0.5).should_compact(10, [5])
    assert not CompactionPolicy.never().should_compact(1, [10 ** 6] * 100)
    with pytest.raises(ValueError):
        CompactionPolicy(max_deltas=0)
    with pytest.raises(ValueError):
        CompactionPolicy(size_ratio=0.0)

    store = CorpusStore(_col(10, 1), "jaccard", 0.7, plan=_blocked_plan(),
                        policy=CompactionPolicy(max_deltas=2, size_ratio=9.0))
    assert not store.compact()            # nothing to fold
    store.append(_col(2, 2))              # 1 delta: below both triggers
    assert store.stats().delta_count == 1
    store.append(_col(2, 3))              # hits max_deltas -> auto-fold
    assert store.compactions == 1 and store.stats().delta_count == 0
    store.append(_col(2, 4), compact=False)   # explicit suppress
    assert store.compactions == 1 and store.stats().delta_count == 1
    store.append(_col(1, 5), compact=True)    # explicit force
    assert store.compactions == 2 and store.stats().delta_count == 0


def test_empty_store_and_empty_batch():
    store = CorpusStore()       # born empty
    assert store.num_sets == 0
    pairs, stats = store.probe(_col(4, 1))
    assert pairs.shape == (0, 2)
    pairs, stats = store.probe(_col(0, 0))
    assert pairs.shape == (0, 2) and stats.total_pairs == 0
    assert store.self_join().shape == (0, 2)
    store.append(_col(12, 3, "dup_heavy"))
    _assert_probe_identical(store, _col(5, 9, "dup_heavy"))


def test_store_stats_rollup():
    store = CorpusStore(_col(16, 1), "jaccard", 0.7, plan=_blocked_plan(),
                        policy=CompactionPolicy.never())
    store.append(_col(4, 2))
    store.append(_col(4, 3))
    store.probe(_col(3, 4))
    s = store.stats()
    assert isinstance(s, StoreStats)
    assert (s.segments, s.base_rows, s.delta_rows) == (3, 16, 8)
    assert s.delta_count == 2 and s.appends == 2 and s.probes == 1
    assert s.delta_fraction == pytest.approx(8 / 24)
    assert s.delta_builds["sort"] == 2
    d = s.to_dict()
    assert d["compactions"] == 0 and d["builds"]["sort"] == 1
    store.compact()
    s2 = store.stats()
    assert s2.delta_fraction == 0.0
    assert s2.lifetime_builds["sort"] == 4   # base + 2 deltas + merged base


def test_engine_over_store_adopts_and_validates():
    plan = _blocked_plan("cosine", 0.75)
    store = CorpusStore(_col(20, 1, "dup_heavy"), "cosine", 0.75, plan=plan,
                        policy=CompactionPolicy.never())
    store.append(_col(6, 2, "dup_heavy"))
    eng = JoinEngine(store)
    assert eng.sim == "cosine" and eng.tau == 0.75 and eng.plan == plan
    batch = _col(6, 3, "dup_heavy")
    pairs, stats = eng.probe(batch)
    assert np.array_equal(pairs, store.probe(batch, return_stats=False))
    assert eng.probes == 1                      # engine rollup still works
    with pytest.raises(ValueError):
        JoinEngine(store, "jaccard", 0.5)       # conflicting sim/tau
    with pytest.raises(ValueError):
        JoinEngine(store, plan=_blocked_plan("cosine", 0.75, b=64))
    # prepared reads through compaction to the live base
    old_base = eng.prepared
    store.compact()
    assert eng.prepared is store.base.prepared is not old_base

    other = JoinEngine(_col(5, 9), "cosine", 0.75, plan=plan)
    with pytest.raises(ValueError):
        other.attach_store(store)               # not this engine's corpus


def test_store_plan_sim_tau_must_agree():
    with pytest.raises(ValueError):
        CorpusStore(_col(5, 1), "jaccard", 0.8, plan=_blocked_plan("cosine",
                                                                   0.8))
    with pytest.raises(ValueError):
        CorpusStore(_col(5, 1), "jaccard", 0.8, plan=_blocked_plan("jaccard",
                                                                   0.7))


# ---------------------------------------------------------------------------
# Satellite: post-prepare source mutation is a hard error, not silent
# staleness.
# ---------------------------------------------------------------------------

def test_prepared_sources_are_sealed():
    col = _col(8, 1)
    prep = prepare(col)
    with pytest.raises(ValueError):
        col.tokens[0, 0] = 99
    with pytest.raises(ValueError):
        col.lengths[0] = 3
    with pytest.raises(ValueError):
        prep.tokens[0, 0] = 99
    with pytest.raises(ValueError):
        prep.lengths[0] = 3


# ---------------------------------------------------------------------------
# Satellite: the dedup_shards cross-shard duplicate leak.
# ---------------------------------------------------------------------------

def test_dedup_shards_cross_shard_leak_regression():
    """A duplicate pair spanning shard 1 and shard 2 (absent from the
    corpus) used to survive in both shards, because each shard was deduped
    against the original corpus only.  The store wiring makes shard 2 see
    shard 1's survivors."""
    corpus = from_lists([[1, 2, 3, 4, 5], [10, 11, 12, 13],
                         [20, 21, 22, 23, 24]], pad_to=_PAD)
    dup = [40, 41, 42, 43, 44]
    s1 = from_lists([dup, [50, 51, 52]], pad_to=_PAD)
    s2 = from_lists([dup, [60, 61, 62, 63]], pad_to=_PAD)
    res, store = dedup_shards(corpus, [s1, s2], 0.8, b=_B, block=_BLOCK,
                              compaction="host", return_store=True)
    assert list(res[0].keep) == [0, 1]      # first sighting survives
    assert list(res[1].keep) == [1]         # the cross-shard dup is dropped
    assert 0 in res[1].drop_vs_corpus
    # The store holds exactly the deduped union; its ids are append-global.
    assert store.num_sets == 3 + 2 + 1
    # Old behavior for contrast: corpus-only dedup keeps both copies.
    assert list(dedup_against(corpus, s2, 0.8, b=_B, block=_BLOCK,
                              compaction="host").keep) == [0, 1]


def test_dedup_shards_survivor_set_is_pairwise_dissimilar():
    """The defining post-condition of leak-free streaming dedup: starting
    from a deduped base, every pair of surviving documents — across the
    base and ALL shards — is below τ, i.e. the final store's self-join is
    empty.  Under the old corpus-only wiring, cross-shard duplicates both
    survive and this self-join is non-empty."""
    from repro.data.dedup import dedup_collection

    big = _col(44, 7, "dup_heavy")   # one dup-heavy pool sliced into shards,
    # so near-duplicates genuinely span the shard boundaries

    def rows(a, b):
        return Collection(tokens=big.tokens[a:b].copy(),
                          lengths=big.lengths[a:b].copy())

    raw = rows(0, 14)
    base = dedup_collection(raw, 0.7, b=_B, block=_BLOCK, compaction="host")
    corpus = Collection(tokens=raw.tokens[base.keep],
                        lengths=raw.lengths[base.keep])
    shards = [rows(14, 24), rows(24, 34), rows(34, 44)]
    res, store = dedup_shards(corpus, shards, 0.7, b=_B, block=_BLOCK,
                              compaction="host", return_store=True)

    assert len(store.self_join()) == 0
    # The leak scenario must actually have been exercised: some document
    # was dropped against a *prior shard's survivor* (a store-global id
    # beyond the original corpus), which corpus-only dedup cannot see.
    assert any(len(r.pairs_rs) and r.pairs_rs[:, 0].max() >= corpus.num_sets
               for r in res)


# ---------------------------------------------------------------------------
# Serving integration: append between coalesced batches, no retrace.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_session_append_no_retrace_and_exact():
    from repro.serve import JoinSession

    plan = JoinPlan(driver="indexed", sim="jaccard", tau=0.7, b=_B, block=64)
    sess = JoinSession(_col(60, 1, "dup_heavy"), "jaccard", 0.7, plan=plan,
                       policy=CompactionPolicy.never())
    batch = _col(8, 99, "dup_heavy")
    p0, _ = sess.probe(batch)
    traces0 = sess.entrypoints.stats()["traces"]

    for i in range(3):
        sess.append(_col(10, 10 + i, "dup_heavy"), compact=False)
        pairs, stats = sess.probe(batch)
        # Warm entrypoints keep serving the untouched base: zero new traces
        # across appends (the resident no-retrace contract).
        assert sess.entrypoints.stats()["traces"] == traces0
        op, os_ = _oracle(sess.store).probe(batch)
        assert np.array_equal(pairs, op)
        for f in PROBE_SUM_FIELDS:
            assert getattr(stats, f) == getattr(os_, f), f

    # The coalesced fast path and the sequential engine path agree per
    # request even with live deltas.
    seq_pairs, seq_stats = sess.engine.probe(batch)
    pairs, stats = sess.probe(batch)
    assert np.array_equal(pairs, seq_pairs)
    assert stats.to_dict() == seq_stats.to_dict()

    assert sess.compact()
    pairs, _ = sess.probe(batch)
    assert np.array_equal(pairs, _oracle(sess.store).probe(
        batch, return_stats=False))
    assert sess.stats_summary()["store"]["compactions"] == 1


@pytest.mark.slow
def test_session_over_store_and_policy_autofold():
    from repro.serve import JoinSession

    plan = JoinPlan(driver="indexed", sim="jaccard", tau=0.7, b=_B, block=64)
    store = CorpusStore(_col(40, 1, "dup_heavy"), "jaccard", 0.7, plan=plan,
                        policy=CompactionPolicy(max_deltas=2, size_ratio=9.0))
    store.append(_col(5, 2, "dup_heavy"), compact=False)
    sess = JoinSession(store)           # construct directly over a store
    assert sess.plan == plan and sess.store is store
    batch = _col(6, 9, "dup_heavy")
    pairs, _ = sess.probe(batch)
    assert np.array_equal(pairs, _oracle(store).probe(batch,
                                                      return_stats=False))
    sess.append(_col(5, 3, "dup_heavy"))    # hits max_deltas -> auto-fold
    assert store.compactions == 1 and not store.deltas
    pairs, _ = sess.probe(batch)            # session rebound to the new base
    assert np.array_equal(pairs, _oracle(store).probe(batch,
                                                      return_stats=False))
