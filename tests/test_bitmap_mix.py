"""Bitmap width validation + the Knuth-mixer (``mix=True``) hash path.

Satellites of the engine PR: ``generate_bitmaps``/``pack_bits`` must reject
widths that would silently mis-pack, and the multiplicative-mixer hash —
previously exercised nowhere — must preserve exactness through every
generation method, similarity function and threshold (Theorem 1 holds for
*any* hash, so the joins must still match the ``naive_join`` oracle
bit-for-bit).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitmap as bm
from repro.core import join
from repro.core.collection import from_lists
from repro.core.constants import BITMAP_NEXT, BITMAP_SET, BITMAP_XOR
from repro.core.filters import BitmapFilter

_PAD = 16


def _collection(seed: int = 0, n: int = 48):
    rng = np.random.default_rng(seed)
    sets = [rng.choice(110, size=rng.integers(1, 13), replace=False).tolist()
            for _ in range(n)]
    sets[n // 2] = sets[0]  # planted duplicate -> non-empty joins
    return from_lists(sets, pad_to=_PAD)


# ---------------------------------------------------------------------------
# Width validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad_b", [0, -32, 7, 31, 33, 48])
def test_generate_bitmaps_rejects_bad_widths(bad_b):
    col = _collection()
    with pytest.raises(ValueError, match="multiple of 32"):
        bm.generate_bitmaps(jnp.asarray(col.tokens), jnp.asarray(col.lengths),
                            bad_b, method=BITMAP_XOR)


def test_pack_bits_rejects_bad_widths():
    with pytest.raises(ValueError, match="multiple of 32"):
        bm.pack_bits(jnp.zeros((4, 48), dtype=bool))
    with pytest.raises(ValueError, match="multiple of 32"):
        bm.pack_bits(jnp.zeros((4, 0), dtype=bool))


def test_generate_bitmaps_rejects_unknown_method():
    col = _collection()
    with pytest.raises(ValueError, match="unknown bitmap method"):
        bm.generate_bitmaps(jnp.asarray(col.tokens), jnp.asarray(col.lengths),
                            32, method="bloom")


def test_valid_width_still_works():
    col = _collection()
    words = bm.generate_bitmaps(jnp.asarray(col.tokens),
                                jnp.asarray(col.lengths), 32,
                                method=BITMAP_XOR)
    assert words.shape == (col.num_sets, 1)


# ---------------------------------------------------------------------------
# Knuth-mixer hash path
# ---------------------------------------------------------------------------

def test_hash_positions_mix_in_range_and_differs():
    tokens = jnp.arange(0, 512, dtype=jnp.int32)
    plain = np.asarray(bm.hash_positions(tokens, 64, mix=False))
    mixed = np.asarray(bm.hash_positions(tokens, 64, mix=True))
    assert plain.min() >= 0 and plain.max() < 64
    assert mixed.min() >= 0 and mixed.max() < 64
    # the mixer actually permutes the distribution (not a no-op)
    assert not np.array_equal(plain, mixed)


SIM_TAUS = [("jaccard", 0.5), ("jaccard", 0.85), ("cosine", 0.7),
            ("dice", 0.75), ("overlap", 3.0)]


@pytest.mark.parametrize("method", [BITMAP_SET, BITMAP_XOR, BITMAP_NEXT])
@pytest.mark.parametrize("sim,tau", SIM_TAUS)
def test_mix_join_matches_oracle(method, sim, tau):
    """Eq. 2 is hash-agnostic: the mixed-hash bitmap filter must prune only
    pairs exact verification would reject, for every generation method."""
    # Deterministic per-parametrization seed (str hashes are salted per
    # process — a failure must be reproducible).
    seed = (sum(map(ord, method + sim)) + int(tau * 100)) % 1000
    col = _collection(seed=seed)
    oracle = join.naive_join(col, sim, tau)
    got = join.blocked_bitmap_join(col, sim, tau, b=32, method=method,
                                   mix=True, block=16)
    assert np.array_equal(oracle, got), (method, sim, tau, len(oracle),
                                         len(got))


def test_mix_join_matches_oracle_device_compaction_rs():
    rng = np.random.default_rng(5)
    col_r = _collection(seed=5)
    sets_s = [rng.choice(110, size=rng.integers(1, 13), replace=False).tolist()
              for _ in range(32)]
    sets_s[0] = list(col_r.row(0))
    col_s = from_lists(sets_s, pad_to=_PAD)
    oracle = join.naive_join(col_r, col_s, "jaccard", 0.7)
    got = join.blocked_bitmap_join(col_r, col_s, "jaccard", 0.7, b=32,
                                   method=BITMAP_XOR, mix=True, block=16,
                                   compaction="device")
    assert np.array_equal(oracle, got)


def test_bitmap_filter_mix_cpu_algo_matches_oracle():
    from repro.core import cpu_algos
    from repro.core.collection import preprocess

    col = preprocess(_collection(seed=9, n=40))
    bf = BitmapFilter.build(col.tokens, col.lengths, "jaccard", 0.6, b=64,
                            mix=True)
    oracle = join.naive_join(col, "jaccard", 0.6)
    got = cpu_algos.ppjoin(col, "jaccard", 0.6, bitmap=bf)
    assert np.array_equal(oracle, got)
