"""Prepared-collection engine: build-once artifacts, the planner, and the
batched probe API.

Covers the contract the engine layer promises:

* a ``PreparedCollection`` builds each artifact (length sort, bitmap words
  per ``(b, method, mix)``, integer length windows, CPU prefix index) at most
  once — assertable via its build counters;
* every driver (blocked host/device, naive, ring, all four CPU algorithms)
  accepts prepared inputs and returns the exact oracle pair set in original
  indices, bit-identical to the plain-``Collection`` wrappers;
* ``JoinPlanner`` resolves workloads into explicit, inspectable plans;
* ``JoinEngine.probe`` streams batches against one prepared corpus with
  per-batch ``JoinStats`` and no corpus-side rebuilds.
"""

import numpy as np
import pytest

from repro.core import cpu_algos, join
from repro.core.collection import Collection, from_lists, preprocess, preprocess_rs
from repro.core.engine import (
    JoinEngine,
    PreparedCollection,
    prepare,
    prepared_bitmap_filter,
)
from repro.core.plan import JoinPlan, JoinPlanner
from repro.data.dedup import dedup_against, dedup_shards

_PAD = 16


def _collection(seed: int, n: int = 48, universe: int = 110):
    rng = np.random.default_rng(seed)
    sets = [rng.choice(universe, size=rng.integers(1, 13), replace=False).tolist()
            for _ in range(n)]
    return from_lists(sets, pad_to=_PAD)


def _rs_pair(seed: int, n_r: int = 48, n_s: int = 32):
    rng = np.random.default_rng(seed)
    col_r = _collection(seed, n=n_r)
    sets_s = [rng.choice(110, size=rng.integers(1, 13), replace=False).tolist()
              for _ in range(n_s)]
    for k in range(min(6, n_s)):
        sets_s[k] = list(col_r.row((3 * k) % n_r))
    return col_r, from_lists(sets_s, pad_to=_PAD)


# ---------------------------------------------------------------------------
# PreparedCollection artifacts
# ---------------------------------------------------------------------------

def test_prepare_is_idempotent_and_sorts_stably():
    col = _collection(0)
    prep = prepare(col)
    assert prepare(prep) is prep
    assert np.array_equal(prep.order, np.argsort(col.lengths, kind="stable"))
    assert np.array_equal(prep.lengths, np.sort(col.lengths, kind="stable"))
    assert np.array_equal(prep.order[prep.inverse], np.arange(col.num_sets))
    # duck-typed Collection surface over the sorted view
    assert prep.num_sets == col.num_sets and prep.max_len == col.max_len
    assert np.array_equal(prep.row(0), col.row(int(prep.order[0])))


def test_bitmap_words_cached_per_key():
    prep = prepare(_collection(1))
    w1 = prep.bitmap_words(64, "xor")
    w2 = prep.bitmap_words(64, "xor")
    assert w1 is w2
    assert prep.builds["bitmap"] == 1
    prep.bitmap_words(64, "set")
    prep.bitmap_words(32, "xor")
    prep.bitmap_words(64, "xor", mix=True)  # distinct (b, method, mix) keys
    assert prep.builds["bitmap"] == 4
    # 'combined' resolves through Algorithm 6 and shares the resolved key
    from repro.core.bitmap import choose_method
    resolved = choose_method(0.9, 64)
    prep.bitmap_words(64, resolved)     # ensure the resolved key exists
    n = prep.builds["bitmap"]
    prep.bitmap_words(64, "combined", tau=0.9)
    assert prep.builds["bitmap"] == n   # combined hit the resolved key's cache
    with pytest.raises(ValueError, match="combined"):
        prep.bitmap_words(64, "combined")


def test_window_and_prefix_index_cached():
    prep = prepare(_collection(2))
    prep.length_window_int("jaccard", 0.8)
    prep.length_window_int("jaccard", 0.8)
    prep.length_window_int("cosine", 0.8)
    assert prep.builds["window"] == 2
    i1 = prep.prefix_index("jaccard", 0.8)
    i2 = prep.prefix_index("jaccard", 0.8)
    assert i1 is i2
    prep.prefix_index("jaccard", 0.8, ell=3)
    assert prep.builds["prefix_index"] == 2
    lo, hi, _, _ = prep.length_window_int("jaccard", 0.8)
    from repro.core import bounds
    elo, ehi = bounds.length_window_int("jaccard", 0.8, prep.lengths)
    assert np.array_equal(lo, elo) and np.array_equal(hi, ehi)


# ---------------------------------------------------------------------------
# Drivers accept prepared inputs (wrapper parity + oracle exactness)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compaction", ["host", "device"])
def test_blocked_join_prepared_parity_self(compaction):
    col = _collection(3)
    oracle = join.naive_join(col, "jaccard", 0.6)
    plain, pstats = join.blocked_bitmap_join(
        col, "jaccard", 0.6, b=32, block=16, compaction=compaction,
        return_stats=True)
    prep = prepare(col)
    got, gstats = join.blocked_bitmap_join(
        prep, "jaccard", 0.6, b=32, block=16, compaction=compaction,
        return_stats=True)
    assert np.array_equal(oracle, plain)
    assert np.array_equal(oracle, got)
    assert pstats == gstats  # bit-for-bit counters through the wrapper
    # the second prepared call rebuilds nothing
    before = prep.build_counts()
    again = join.blocked_bitmap_join(
        prep, "jaccard", 0.6, b=32, block=16, compaction=compaction)
    assert np.array_equal(oracle, again)
    assert prep.build_counts() == before
    assert prep.builds["sort"] == 1 and prep.builds["bitmap"] == 1


@pytest.mark.parametrize("compaction", ["host", "device"])
def test_blocked_join_prepared_parity_rs(compaction):
    col_r, col_s = _rs_pair(4)
    oracle = join.naive_join(col_r, col_s, "cosine", 0.7)
    plain, pstats = join.blocked_bitmap_join(
        col_r, col_s, "cosine", 0.7, b=32, block=16, compaction=compaction,
        return_stats=True)
    pr, ps = prepare(col_r), prepare(col_s)
    got, gstats = join.blocked_bitmap_join(
        pr, ps, "cosine", 0.7, b=32, block=16, compaction=compaction,
        return_stats=True)
    assert np.array_equal(oracle, plain)
    assert np.array_equal(oracle, got)
    assert pstats == gstats


def test_same_prepared_object_twice_is_full_rs_not_self_join():
    """Passing one prepared object as both R and S must mean R×S over the
    full cross product (diagonal included) — identical to the
    plain-Collection call — not silently flip to self-join semantics."""
    col = _collection(19, n=24)
    prep = prepare(col)
    oracle = join.naive_join(col, col, "jaccard", 0.6)
    assert len(oracle) >= col.num_sets  # at least the diagonal matches
    got = join.blocked_bitmap_join(prep, prep, "jaccard", 0.6, b=32, block=16)
    assert np.array_equal(oracle, got)
    from repro.launch.mesh import make_mesh
    ring = join.ring_join_prepared(prep, prep, mesh=make_mesh((1,), ("data",)),
                                   axis="data", sim="jaccard", tau=0.6, b=32)
    assert np.array_equal(oracle, ring)


def test_naive_join_accepts_prepared():
    col_r, col_s = _rs_pair(5)
    oracle = join.naive_join(col_r, col_s, "jaccard", 0.7)
    got = join.naive_join(prepare(col_r), prepare(col_s), "jaccard", 0.7)
    assert np.array_equal(oracle, got)
    self_oracle = join.naive_join(col_r, "jaccard", 0.7)
    assert np.array_equal(self_oracle,
                          join.naive_join(prepare(col_r), "jaccard", 0.7))


def test_ring_join_prepared_single_device_mesh():
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    col = preprocess(_collection(6))
    oracle = join.naive_join(col, "jaccard", 0.7)
    prep = prepare(col)
    pairs = join.ring_join_prepared(prep, mesh=mesh, axis="data",
                                    sim="jaccard", tau=0.7, b=32)
    assert np.array_equal(oracle, pairs)
    col_r, col_s = _rs_pair(7)
    oracle_rs = join.naive_join(col_r, col_s, "jaccard", 0.6)
    pairs_rs, counters, overflow = join.ring_join_prepared(
        prepare(col_r), prepare(col_s), mesh=mesh, axis="data",
        sim="jaccard", tau=0.6, b=32, return_stats=True)
    assert np.array_equal(oracle_rs, pairs_rs)
    assert counters[:, 1].sum() == len(pairs_rs)


@pytest.mark.parametrize("algo_name", sorted(cpu_algos.ALGORITHMS))
def test_cpu_algos_accept_prepared(algo_name):
    algo = cpu_algos.ALGORITHMS[algo_name]
    col = preprocess(_collection(8, n=40, universe=70))
    oracle = join.naive_join(col, "jaccard", 0.6)
    prep = prepare(col)
    bf = prepared_bitmap_filter(prep, sim="jaccard", tau=0.6, b=64)
    stats = cpu_algos.AlgoStats()
    got = algo(prep, "jaccard", 0.6, bitmap=bf, stats=stats)
    assert np.array_equal(oracle, got), (algo_name, len(oracle), len(got))
    assert stats.results == len(oracle)
    # R×S flavour with a cross-collection prepared filter
    col_r, col_s = preprocess_rs(*_rs_pair(9, n_r=40, n_s=24))
    pr, ps = prepare(col_r), prepare(col_s)
    bf_rs = prepared_bitmap_filter(pr, ps, sim="jaccard", tau=0.6, b=64)
    oracle_rs = join.naive_join(col_r, col_s, "jaccard", 0.6)
    got_rs = algo(pr, ps, "jaccard", 0.6, bitmap=bf_rs)
    assert np.array_equal(oracle_rs, got_rs), (algo_name, len(oracle_rs),
                                               len(got_rs))


def test_cpu_prefix_index_reused_across_calls():
    col = preprocess(_collection(10, n=40, universe=70))
    prep = prepare(col)
    cpu_algos.allpairs(prep, "jaccard", 0.7)
    builds = prep.build_counts()
    assert builds["prefix_index"] == 1
    cpu_algos.ppjoin(prep, "jaccard", 0.7)  # same (sim, tau, ell=1) index
    assert prep.build_counts() == builds


# ---------------------------------------------------------------------------
# JoinPlanner
# ---------------------------------------------------------------------------

def test_planner_picks_naive_for_tiny_inputs():
    plan = JoinPlanner().plan("jaccard", 0.8, n_r=20, n_s=20,
                              backend="cpu", n_devices=1)
    assert plan.driver == "naive"
    assert any("naive" in r for r in plan.reasons)


def test_planner_blocked_on_single_device_and_ring_on_many():
    p1 = JoinPlanner().plan("jaccard", 0.8, n_r=5000,
                            backend="cpu", n_devices=1)
    assert p1.driver == "blocked" and p1.compaction == "host"
    p2 = JoinPlanner().plan("jaccard", 0.8, n_r=5000,
                            backend="tpu", n_devices=8)
    assert p2.driver == "ring" and p2.compaction == "device"


def test_planner_cpu_preference_and_method_resolution():
    lo = JoinPlanner().plan("jaccard", 0.5, n_r=5000, prefer="cpu",
                            backend="cpu", n_devices=1)
    hi = JoinPlanner().plan("jaccard", 0.9, n_r=5000, prefer="cpu",
                            backend="cpu", n_devices=1)
    assert lo.driver == "adaptjoin" and hi.driver == "ppjoin"
    from repro.core.bitmap import choose_method
    assert hi.method == choose_method(0.9, hi.b)
    assert hi.method != "combined"


def test_plan_is_inspectable_and_validated():
    plan = JoinPlanner().plan("dice", 0.75, n_r=3000, backend="cpu",
                              n_devices=1)
    d = plan.to_dict()
    assert d["driver"] == plan.driver and isinstance(d["reasons"], list)
    assert "JoinPlan[" in plan.describe()
    import json as _json
    assert _json.loads(plan.to_json())["sim"] == "dice"
    with pytest.raises(ValueError, match="driver"):
        JoinPlan(driver="warp", sim="jaccard", tau=0.8)
    with pytest.raises(ValueError, match="multiple of 32"):
        JoinPlan(driver="blocked", sim="jaccard", tau=0.8, b=48)
    with pytest.raises(ValueError, match="compaction"):
        JoinPlan(driver="blocked", sim="jaccard", tau=0.8, compaction="gpu")
    with pytest.raises(ValueError, match="prefer"):
        JoinPlanner().plan("jaccard", 0.8, n_r=10, prefer="quantum",
                           backend="cpu", n_devices=1)


# ---------------------------------------------------------------------------
# JoinEngine.probe — the serving shape
# ---------------------------------------------------------------------------

def test_engine_probe_streams_batches_without_rebuilds():
    corpus, batch_all = _rs_pair(11, n_r=60, n_s=40)
    # split S into two batches; the engine must reproduce the full R×S oracle
    half = batch_all.num_sets // 2
    b1 = Collection(tokens=batch_all.tokens[:half],
                    lengths=batch_all.lengths[:half])
    b2 = Collection(tokens=batch_all.tokens[half:],
                    lengths=batch_all.lengths[half:])
    engine = JoinEngine(corpus, "jaccard", 0.7,
                        planner=JoinPlanner(b=32, block=16, naive_cells=0))
    assert engine.plan.driver == "blocked"
    p1, s1 = engine.probe(b1)
    p2, s2 = engine.probe(b2)
    oracle = join.naive_join(corpus, batch_all, "jaccard", 0.7)
    merged = np.concatenate([p1, p2 + np.array([0, half])], axis=0)
    merged = merged[np.lexsort((merged[:, 1], merged[:, 0]))]
    assert np.array_equal(oracle, merged)
    for s in (s1, s2):
        assert s.verified_true <= s.candidates <= s.total_pairs
    # corpus artifacts built exactly once across both probes
    assert engine.prepared.builds["sort"] == 1
    assert engine.prepared.builds["bitmap"] == 1
    assert engine.probes == 2 and len(engine.history) == 2
    # a re-probe of a prepared batch rebuilds nothing anywhere
    pb = prepare(b1)
    engine.probe(pb)
    before = (engine.prepared.build_counts(), pb.build_counts())
    pairs_again = engine.probe(pb, return_stats=False)
    assert np.array_equal(pairs_again, p1)
    assert (engine.prepared.build_counts(), pb.build_counts()) == before


def test_engine_history_capped_but_rollup_counts_all():
    """history is a bounded deque (resident sessions must not grow without
    bound) while stats_summary() keeps lifetime totals over every probe."""
    corpus, batch = _rs_pair(13, n_r=40, n_s=6)
    engine = JoinEngine(corpus, "jaccard", 0.7, history_limit=3,
                        planner=JoinPlanner(b=32, block=16, naive_cells=0))
    stats_seen = []
    for _ in range(5):
        _, s = engine.probe(batch)
        stats_seen.append(s)
    assert engine.probes == 5
    assert len(engine.history) == 3 and engine.history.maxlen == 3
    assert list(engine.history) == stats_seen[-3:]  # newest kept
    summary = engine.stats_summary()
    assert summary["probes"] == 5
    assert summary["history_len"] == 3 and summary["history_limit"] == 3
    # lifetime rollup sums ALL 5 probes, not just the surviving history
    assert summary["total_pairs"] == 5 * stats_seen[0].total_pairs
    assert summary["candidates"] == 5 * stats_seen[0].candidates
    assert 0.0 <= summary["filter_ratio"] <= 1.0
    assert 0.0 <= summary["precision"] <= 1.0


def test_engine_naive_plan_and_self_join():
    col = _collection(12, n=20)
    engine = JoinEngine(col, "jaccard", 0.6)  # tiny -> naive plan
    assert engine.plan.driver == "naive"
    pairs, stats = engine.probe(col)
    assert np.array_equal(pairs, join.naive_join(col, col, "jaccard", 0.6))
    assert stats.verified_true == len(pairs)
    self_pairs = engine.self_join()
    assert np.array_equal(self_pairs, join.naive_join(col, "jaccard", 0.6))


def test_engine_naive_plan_guard_escalates_on_large_batches():
    """An auto-planned 'naive' driver (chosen from the corpus size alone)
    must not run the dense oracle on a batch that blows past the planner's
    own cell threshold — it escalates to the blocked driver per probe."""
    corpus = _collection(20, n=16)
    engine = JoinEngine(corpus, "jaccard", 0.7,
                        planner=JoinPlanner(b=32, naive_cells=600))
    assert engine.plan.driver == "naive"  # 16*16 = 256 <= 600
    small, _ = engine.probe(_collection(21, n=20))   # 320 cells: stays naive
    assert not engine.fallbacks
    _, big_batch = _rs_pair(22, n_r=16, n_s=60)
    big, _ = engine.probe(big_batch)                 # 960 cells: escalates
    assert engine.fallbacks and "blocked" in engine.fallbacks[-1]
    assert np.array_equal(
        big, join.naive_join(corpus, big_batch, "jaccard", 0.7))
    # an explicit user-chosen plan is respected, no second-guessing
    explicit = JoinEngine(corpus, "jaccard", 0.7, plan=engine.plan)
    explicit.probe(big_batch)
    assert not explicit.fallbacks


def test_engine_ring_stats_report_evaluated_grid():
    from repro.launch.mesh import make_mesh

    col_r, col_s = _rs_pair(23, n_r=40, n_s=24)
    plan = JoinPlanner(b=32, naive_cells=0).plan(
        "jaccard", 0.6, n_r=col_r.num_sets, backend="cpu", n_devices=8)
    engine = JoinEngine(col_r, "jaccard", 0.6, plan=plan,
                        mesh=make_mesh((1,), ("data",)), axis="data")
    pairs, stats = engine.probe(col_s)
    nnz = int((col_r.lengths > 0).sum()) * int((col_s.lengths > 0).sum())
    assert stats.total_pairs == nnz
    assert stats.verified_true == len(pairs)
    assert stats.verified_true <= stats.candidates <= stats.total_pairs
    assert 0.0 <= stats.filter_ratio <= 1.0


def test_engine_cpu_plan_matches_oracle():
    col_r, col_s = preprocess_rs(*_rs_pair(13, n_r=40, n_s=24))
    plan = JoinPlanner(b=64).plan("jaccard", 0.7, n_r=col_r.num_sets,
                                  prefer="cpu", backend="cpu", n_devices=1)
    engine = JoinEngine(col_r, "jaccard", 0.7, plan=plan)
    pairs, stats = engine.probe(col_s)
    oracle = join.naive_join(col_r, col_s, "jaccard", 0.7)
    assert np.array_equal(oracle, pairs)
    assert stats.verified_true == len(oracle)
    assert stats.candidates <= stats.total_pairs


def test_engine_ring_plan_without_mesh_falls_back_to_blocked():
    col_r, col_s = _rs_pair(14, n_r=40, n_s=24)
    plan = JoinPlanner(b=32, naive_cells=0).plan(
        "jaccard", 0.7, n_r=col_r.num_sets, backend="cpu", n_devices=8)
    assert plan.driver == "ring"
    engine = JoinEngine(col_r, "jaccard", 0.7, plan=plan)
    pairs, _ = engine.probe(col_s)
    assert np.array_equal(pairs, join.naive_join(col_r, col_s, "jaccard", 0.7))
    assert engine.fallbacks and "blocked" in engine.fallbacks[0]


def test_engine_ring_plan_with_mesh():
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    col_r, col_s = _rs_pair(15, n_r=40, n_s=24)
    plan = JoinPlanner(b=32, naive_cells=0).plan(
        "jaccard", 0.6, n_r=col_r.num_sets, backend="cpu", n_devices=8)
    assert plan.driver == "ring"
    engine = JoinEngine(col_r, "jaccard", 0.6, plan=plan, mesh=mesh,
                        axis="data")
    pairs, stats = engine.probe(col_s)
    assert np.array_equal(pairs, join.naive_join(col_r, col_s, "jaccard", 0.6))
    assert stats.verified_true == len(pairs)
    assert not engine.fallbacks


# ---------------------------------------------------------------------------
# Dedup pipeline reuses one prepared corpus across shards
# ---------------------------------------------------------------------------

def test_dedup_against_prepared_corpus_matches_plain():
    corpus, shard = _rs_pair(16, n_r=50, n_s=30)
    plain = dedup_against(corpus, shard, 0.8, b=32, block=16,
                          compaction="host")
    prep = prepare(corpus)
    got = dedup_against(prep, shard, 0.8, b=32, block=16, compaction="host")
    assert np.array_equal(plain.keep, got.keep)
    assert np.array_equal(plain.pairs_rs, got.pairs_rs)


def test_dedup_shards_prepares_corpus_once():
    corpus, s1 = _rs_pair(17, n_r=50, n_s=20)
    _, s2 = _rs_pair(18, n_r=50, n_s=20)
    prep = prepare(corpus)
    results = dedup_shards(prep, [s1, s2], 0.8, b=32, block=16,
                           compaction="host", within=False)
    assert len(results) == 2
    assert prep.builds["sort"] == 1 and prep.builds["bitmap"] == 1
    for res, shard in zip(results, (s1, s2)):
        ref = dedup_against(corpus, shard, 0.8, b=32, block=16,
                            compaction="host", within=False)
        assert np.array_equal(res.keep, ref.keep)
