"""Trajectory plumbing + perf-regression gate.

Three contracts the benchmark stack's protection rests on:

* ``benchmarks.run.parse_args`` — bare ``--trajectory`` must not swallow the
  following token (it is a module filter, not a path; the old behaviour
  silently wrote a file named after the filter in cwd);
* ``benchmarks.run.append_trajectory`` — a corrupt history file is moved
  aside (``.corrupt``), never silently replaced: the trajectory is the
  cross-PR perf history the gate runs on;
* ``benchmarks.perf_gate`` — regressions >ratio on gated rows fail, new rows
  and noise-floor baselines skip, the env waiver downgrades to a warning.
"""

import json
import os

import pytest

from benchmarks.common import Row
from benchmarks import perf_gate
from benchmarks.run import (append_trajectory, default_trajectory,
                            parse_args)


# ---------------------------------------------------------------------------
# --trajectory argument parsing
# ---------------------------------------------------------------------------

def test_bare_trajectory_does_not_swallow_filters():
    args = parse_args(["--trajectory", "bench_engine"])
    assert args.trajectory_path == default_trajectory()
    assert args.filters == ["bench_engine"]


def test_explicit_trajectory_path_requires_equals(tmp_path):
    p = str(tmp_path / "t.json")
    args = parse_args([f"--trajectory={p}", "bench_kernels", "--smoke"])
    assert args.trajectory_path == p
    assert args.filters == ["bench_kernels"]
    assert args.smoke


def test_empty_trajectory_value_resolves_default():
    args = parse_args(["--trajectory="])
    assert args.trajectory_path == default_trajectory()


def test_no_trajectory_flag_means_no_append():
    args = parse_args(["bench_engine"])
    assert args.trajectory_path is None
    assert args.filters == ["bench_engine"]


def test_json_forms_and_unknown_flag():
    assert parse_args(["--json", "x.json"]).json_path == "x.json"
    assert parse_args(["--json=y.json"]).json_path == "y.json"
    with pytest.raises(SystemExit):
        parse_args(["--json"])
    with pytest.raises(SystemExit):
        parse_args(["--frobnicate"])


def test_default_trajectory_is_newest_bench_pr():
    d = default_trajectory()
    assert os.path.basename(d).startswith("BENCH_PR")
    # The repo ships BENCH_PR3/4/5/7 — newest must win, without a manual bump.
    import glob
    import re
    root = os.path.dirname(d)
    nums = [int(re.fullmatch(r"BENCH_PR(\d+)\.json", os.path.basename(p)).group(1))
            for p in glob.glob(os.path.join(root, "BENCH_PR*.json"))
            if re.fullmatch(r"BENCH_PR(\d+)\.json", os.path.basename(p))]
    assert os.path.basename(d) == f"BENCH_PR{max(nums)}.json"


# ---------------------------------------------------------------------------
# append_trajectory: corruption round trip
# ---------------------------------------------------------------------------

def _rows(us=100.0):
    return [Row("kernel_pair_verdict_b128_g16384", us, "derived",
                stats={"roofline": {"hbm_bytes": 1.0, "flops": 0.0,
                                    "achieved_bytes_s": 1.0,
                                    "bottleneck": "memory", "gap": 2.0}}),
            Row("kernel_entry_filter_g131072", us * 2, "derived"),
            Row("ungated_row", us, "derived")]


def test_append_and_round_trip(tmp_path):
    p = str(tmp_path / "traj.json")
    assert append_trajectory(p, _rows(), smoke=True) == 1
    assert append_trajectory(p, _rows(110.0), smoke=True) == 2
    with open(p) as f:
        hist = json.load(f)
    assert [e["smoke"] for e in hist] == [True, True]
    assert hist[1]["rows"][0]["us_per_call"] == 110.0
    assert hist[0]["rows"][0]["stats"]["roofline"]["bottleneck"] == "memory"


@pytest.mark.parametrize("garbage", ['{"truncated": [1, 2', '{"not": "a list"}'])
def test_corrupt_trajectory_moved_aside_not_destroyed(tmp_path, garbage, capsys):
    p = str(tmp_path / "traj.json")
    with open(p, "w") as f:
        f.write(garbage)
    n = append_trajectory(p, _rows(), smoke=False)
    assert n == 1
    # the corrupt bytes survive under .corrupt; the new history is fresh
    with open(p + ".corrupt") as f:
        assert f.read() == garbage
    with open(p) as f:
        hist = json.load(f)
    assert len(hist) == 1 and hist[0]["smoke"] is False
    assert "moved aside" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# the gate itself
# ---------------------------------------------------------------------------

def _entry(us, smoke=True, name="kernel_pair_verdict_b128_g16384"):
    return {"ts": "t", "rev": "r", "smoke": smoke,
            "rows": [{"name": name, "us_per_call": us, "derived": ""}]}


def test_gate_passes_within_threshold():
    hist = [_entry(100.0), _entry(120.0)]
    (v,) = perf_gate.check_trajectory(hist, ratio=1.3)
    assert v.status == "ok" and v.ratio == pytest.approx(1.2)


def test_gate_fails_on_regression():
    hist = [_entry(100.0), _entry(140.0)]
    (v,) = perf_gate.check_trajectory(hist, ratio=1.3)
    assert v.status == "fail"
    assert v.baseline_us == 100.0


def test_gate_baseline_is_min_of_lookback():
    # a noisy slow prior must not raise the baseline
    hist = [_entry(100.0), _entry(500.0), _entry(125.0)]
    (v,) = perf_gate.check_trajectory(hist, ratio=1.3)
    assert v.status == "ok" and v.baseline_us == 100.0
    # ... but only the last LOOKBACK priors count
    hist = [_entry(50.0)] + [_entry(200.0)] * perf_gate.LOOKBACK + [_entry(200.0)]
    (v,) = perf_gate.check_trajectory(hist, ratio=1.3)
    assert v.baseline_us == 200.0


def test_gate_ignores_other_smoke_flag_and_ungated_rows():
    hist = [_entry(100.0, smoke=False), _entry(1000.0, smoke=True)]
    (v,) = perf_gate.check_trajectory(hist, ratio=1.3)
    assert v.status == "new"  # the smoke=False prior is not a baseline
    hist = [_entry(100.0, name="bench_engine_row"),
            _entry(1000.0, name="bench_engine_row")]
    assert perf_gate.check_trajectory(hist, ratio=1.3) == []


def test_gate_noise_floor_skips_tiny_baselines():
    hist = [_entry(10.0), _entry(40.0)]
    (v,) = perf_gate.check_trajectory(hist, ratio=1.3)
    assert v.status == "noise"


def _write(tmp_path, hist):
    p = str(tmp_path / "traj.json")
    with open(p, "w") as f:
        json.dump(hist, f)
    return p


def test_main_exit_codes(tmp_path, monkeypatch, capsys):
    monkeypatch.delenv(perf_gate.WAIVE_ENV, raising=False)
    monkeypatch.delenv(perf_gate.RATIO_ENV, raising=False)
    # regression -> 1
    p = _write(tmp_path, [_entry(100.0), _entry(140.0)])
    assert perf_gate.main([f"--trajectory={p}"]) == 1
    assert "FAIL" in capsys.readouterr().out
    # within threshold -> 0
    p = _write(tmp_path, [_entry(100.0), _entry(110.0)])
    assert perf_gate.main([f"--trajectory={p}"]) == 0
    # no prior entries with matching rows -> skip-with-warning, 0
    p = _write(tmp_path, [_entry(100.0)])
    assert perf_gate.main([f"--trajectory={p}"]) == 0
    assert "SKIP" in capsys.readouterr().out
    # missing file -> skip, 0
    assert perf_gate.main([f"--trajectory={tmp_path}/nope.json"]) == 0


def test_main_waiver_env(tmp_path, monkeypatch, capsys):
    p = _write(tmp_path, [_entry(100.0), _entry(200.0)])
    monkeypatch.setenv(perf_gate.WAIVE_ENV, "1")
    assert perf_gate.main([f"--trajectory={p}"]) == 0
    assert "WAIVED" in capsys.readouterr().out


def test_main_ratio_env(tmp_path, monkeypatch):
    p = _write(tmp_path, [_entry(100.0), _entry(140.0)])
    monkeypatch.delenv(perf_gate.WAIVE_ENV, raising=False)
    monkeypatch.setenv(perf_gate.RATIO_ENV, "1.5")
    assert perf_gate.main([f"--trajectory={p}"]) == 0


# ---------------------------------------------------------------------------
# latency fields + field-based gating (serving rows)
# ---------------------------------------------------------------------------

def test_row_latency_fields_round_trip(tmp_path):
    p = str(tmp_path / "traj.json")
    rows = [Row("serve_sustained_n600", 300.0, "d",
                stats={"probes_per_sec": 3000.0},
                p50_us=450.0, p99_us=2100.0),
            Row("plain_row", 10.0, "d")]
    append_trajectory(p, rows, smoke=True)
    with open(p) as f:
        (entry,) = json.load(f)
    serve, plain = entry["rows"]
    assert serve["p50_us"] == 450.0 and serve["p99_us"] == 2100.0
    assert serve["stats"]["probes_per_sec"] == 3000.0
    # absent latency fields are omitted, not emitted as null
    assert "p50_us" not in plain and "p99_us" not in plain


def _serve_entry(pps, p99, smoke=True):
    return {"ts": "t", "rev": "r", "smoke": smoke,
            "rows": [{"name": "serve_sustained_n600", "us_per_call": 300.0,
                      "derived": "", "p99_us": p99,
                      "stats": {"probes_per_sec": pps}}]}


def _by_name(verdicts):
    return {v.name: v for v in verdicts}


def test_gate_throughput_inverted_comparison():
    # throughput DROP fails; us_per_call of the row itself is not gated
    hist = [_serve_entry(3000.0, 2000.0), _serve_entry(2000.0, 2000.0)]
    vs = _by_name(perf_gate.check_trajectory(hist, ratio=1.3))
    v = vs["serve_sustained_n600[stats.probes_per_sec]"]
    assert v.status == "fail" and v.ratio == pytest.approx(1.5)
    assert v.baseline_us == 3000.0 and v.unit == "/s"
    # throughput INCREASE (ratio < 1) passes
    hist = [_serve_entry(3000.0, 2000.0), _serve_entry(4000.0, 2000.0)]
    vs = _by_name(perf_gate.check_trajectory(hist, ratio=1.3))
    assert vs["serve_sustained_n600[stats.probes_per_sec]"].status == "ok"


def test_gate_throughput_baseline_is_best_prior():
    # one slow prior must not lower the throughput bar
    hist = [_serve_entry(3000.0, 2000.0), _serve_entry(500.0, 2000.0),
            _serve_entry(2900.0, 2000.0)]
    vs = _by_name(perf_gate.check_trajectory(hist, ratio=1.3))
    v = vs["serve_sustained_n600[stats.probes_per_sec]"]
    assert v.baseline_us == 3000.0 and v.status == "ok"


def test_gate_p99_latency_lower_is_better():
    # a structural tail regression (> ratio * 1.5 margin) fails
    hist = [_serve_entry(3000.0, 2000.0), _serve_entry(3000.0, 4200.0)]
    vs = _by_name(perf_gate.check_trajectory(hist, ratio=1.3))
    v = vs["serve_sustained_n600[p99_us]"]
    assert v.status == "fail" and v.ratio == pytest.approx(2.1)
    # run-to-run p99 jitter inside the margin (1.4x < 1.3 * 1.5) passes
    hist = [_serve_entry(3000.0, 2000.0), _serve_entry(3000.0, 2800.0)]
    vs = _by_name(perf_gate.check_trajectory(hist, ratio=1.3))
    assert vs["serve_sustained_n600[p99_us]"].status == "ok"
    # the us noise floor applies to latency fields too
    hist = [_serve_entry(3000.0, 10.0), _serve_entry(3000.0, 45.0)]
    vs = _by_name(perf_gate.check_trajectory(hist, ratio=1.3))
    assert vs["serve_sustained_n600[p99_us]"].status == "noise"


def test_gate_field_new_without_prior():
    vs = _by_name(perf_gate.check_trajectory([_serve_entry(3000.0, 2000.0)],
                                             ratio=1.3))
    assert vs["serve_sustained_n600[stats.probes_per_sec]"].status == "new"
    assert vs["serve_sustained_n600[p99_us]"].status == "new"


def test_gate_field_absent_is_skipped():
    # rows without the gated fields (e.g. old entries) produce no verdicts
    entry = {"ts": "t", "rev": "r", "smoke": True,
             "rows": [{"name": "serve_sustained_n600", "us_per_call": 1.0,
                       "derived": ""}]}
    assert perf_gate.check_trajectory([entry], ratio=1.3) == []


def test_main_fails_on_throughput_regression(tmp_path, monkeypatch, capsys):
    monkeypatch.delenv(perf_gate.WAIVE_ENV, raising=False)
    monkeypatch.delenv(perf_gate.RATIO_ENV, raising=False)
    p = _write(tmp_path, [_serve_entry(3000.0, 2000.0),
                          _serve_entry(1000.0, 2000.0)])
    assert perf_gate.main([f"--trajectory={p}"]) == 1
    assert "probes_per_sec" in capsys.readouterr().out
