"""Shared test fixtures.

NOTE: no XLA_FLAGS here on purpose — smoke tests and benchmarks must see the
single real CPU device; only the dry-run process forces 512 placeholders.
"""

import numpy as np
import pytest

from repro.core.collection import from_lists, preprocess
from repro.data.collections import uniform_collection, with_duplicates


def _proc_int(path):
    try:
        with open(path) as f:
            return int(f.read())
    except (OSError, ValueError):
        return None


_MAP_CEILING = _proc_int("/proc/sys/vm/max_map_count")


def _map_count() -> int:
    try:
        with open("/proc/self/maps", "rb") as f:
            return sum(1 for _ in f)
    except OSError:
        return 0


@pytest.fixture(autouse=True)
def _relieve_map_pressure():
    """Evict jax's executable caches before vm.max_map_count is exhausted.

    Every distinct jit compile holds JIT code pages alive in the pjit cache;
    a full tier-1 run accumulates enough executables that the process walks
    into the kernel's memory-map ceiling and the *next* XLA compile mmap
    segfaults the interpreter (observed reproducibly mid-suite on default
    vm.max_map_count=65530 hosts).  Recompiles after an eviction are cheap;
    a dead test process is not.  No-op off Linux.
    """
    yield
    if _MAP_CEILING and _map_count() > 0.7 * _MAP_CEILING:
        import jax

        jax.clear_caches()


@pytest.fixture(scope="session")
def small_collection():
    """~200 sets with planted near-duplicate clusters (non-empty join)."""
    base = uniform_collection(n_sets=160, avg_size=12, n_tokens=300, seed=1)
    return with_duplicates(base, n_clusters=10, cluster_size=3, jaccard=0.85, seed=2)


@pytest.fixture(scope="session")
def tiny_collection():
    rng = np.random.default_rng(3)
    sets = [rng.choice(80, size=rng.integers(2, 14), replace=False).tolist()
            for _ in range(60)]
    sets += [sets[i][:-1] + [81 + i] for i in range(0, 20, 2)]  # near-dups
    return preprocess(from_lists(sets))
