"""Shared test fixtures.

NOTE: no XLA_FLAGS here on purpose — smoke tests and benchmarks must see the
single real CPU device; only the dry-run process forces 512 placeholders.
"""

import numpy as np
import pytest

from repro.core.collection import from_lists, preprocess
from repro.data.collections import uniform_collection, with_duplicates


@pytest.fixture(scope="session")
def small_collection():
    """~200 sets with planted near-duplicate clusters (non-empty join)."""
    base = uniform_collection(n_sets=160, avg_size=12, n_tokens=300, seed=1)
    return with_duplicates(base, n_clusters=10, cluster_size=3, jaccard=0.85, seed=2)


@pytest.fixture(scope="session")
def tiny_collection():
    rng = np.random.default_rng(3)
    sets = [rng.choice(80, size=rng.integers(2, 14), replace=False).tolist()
            for _ in range(60)]
    sets += [sets[i][:-1] + [81 + i] for i in range(0, 20, 2)]  # near-dups
    return preprocess(from_lists(sets))
