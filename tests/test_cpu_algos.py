"""The four faithful CPU algorithms (AllPairs/PPJoin/GroupJoin/AdaptJoin)
must return exactly the oracle pairs, with and without the Bitmap Filter,
and the Bitmap Filter must actually prune (effectiveness, Table 9)."""

import numpy as np
import pytest

from repro.core import cpu_algos, join
from repro.core.constants import BITMAP_METHODS
from repro.core.filters import BitmapFilter

ALGOS = list(cpu_algos.ALGORITHMS)


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("sim,tau", [("jaccard", 0.6), ("jaccard", 0.85),
                                     ("cosine", 0.7), ("dice", 0.8)])
def test_algo_matches_oracle(small_collection, algo, sim, tau):
    oracle = join.naive_join(small_collection, sim, tau)
    got = cpu_algos.ALGORITHMS[algo](small_collection, sim, tau)
    assert np.array_equal(oracle, got), (algo, sim, tau, len(oracle), len(got))


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("method", BITMAP_METHODS + ("combined",))
def test_algo_with_bitmap_filter_exact(small_collection, algo, method):
    sim, tau = "jaccard", 0.7
    oracle = join.naive_join(small_collection, sim, tau)
    bf = BitmapFilter.build(small_collection.tokens, small_collection.lengths,
                            sim, tau, b=64, method=method)
    stats = cpu_algos.AlgoStats()
    got = cpu_algos.ALGORITHMS[algo](small_collection, sim, tau,
                                     bitmap=bf, stats=stats)
    assert np.array_equal(oracle, got), (algo, method)
    assert stats.results == len(oracle)


def test_bitmap_filter_prunes(small_collection):
    """The filter must reduce verifications (the paper's whole point)."""
    sim, tau = "jaccard", 0.85
    s0 = cpu_algos.AlgoStats()
    cpu_algos.allpairs(small_collection, sim, tau, stats=s0)
    bf = BitmapFilter.build(small_collection.tokens, small_collection.lengths,
                            sim, tau, b=64)
    s1 = cpu_algos.AlgoStats()
    cpu_algos.allpairs(small_collection, sim, tau, bitmap=bf, stats=s1)
    assert s1.bitmap_pruned > 0
    assert s1.verified < s0.verified
    # ratio comparable to paper Table 9's high-threshold regime
    ratio = s1.bitmap_pruned / max(s1.candidates, 1)
    assert ratio > 0.5, ratio


def test_cutoff_disables_filter_for_large_sets(small_collection):
    bf = BitmapFilter.build(small_collection.tokens, small_collection.lengths,
                            "jaccard", 0.7, b=64)
    big = int(np.argmax(small_collection.lengths))
    js = np.arange(small_collection.num_sets)
    if small_collection.lengths[big] > bf.cutoff:
        assert not bf.prune_mask(big, js).any()
