"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles.

Shape/dtype sweeps per the assignment; all kernels are integer/boolean so the
comparison is exact equality."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bitmap as bm
from repro.core.constants import PAD_TOKEN
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.bitmap_filter import hamming_matrix_pallas, candidate_matrix_pallas
from repro.kernels.bitplane import bitplane_hamming_pallas


def _random_words(n, b, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2 ** 32, size=(n, b // 32), dtype=np.uint32))


def _random_collection_words(n, b, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(1, 40, size=n).astype(np.int32)
    toks = np.full((n, 40), PAD_TOKEN, dtype=np.int32)
    for i, l in enumerate(lens):
        toks[i, :l] = np.sort(rng.choice(5000, size=l, replace=False))
    words = bm.generate_bitmaps(jnp.asarray(toks), jnp.asarray(lens), b, method="xor")
    return words, jnp.asarray(lens)


@pytest.mark.parametrize("b", [64, 128, 256, 1024])
@pytest.mark.parametrize("nr,ns,tile", [(64, 64, 32), (128, 96, 64), (33, 70, 32)])
def test_swar_hamming_matches_ref(b, nr, ns, tile):
    wr = _random_words(nr, b, seed=b + nr)
    ws = _random_words(ns, b, seed=b + ns + 1)
    ref = np.asarray(kref.hamming_matrix_ref(wr, ws))
    got = np.asarray(kops.hamming_matrix(wr, ws, impl="swar", interpret=True, tile=tile))
    assert np.array_equal(ref, got)


@pytest.mark.parametrize("b", [64, 256, 4096])
def test_mxu_bitplane_matches_ref(b):
    wr = _random_words(96, b, seed=7)
    ws = _random_words(64, b, seed=8)
    ref = np.asarray(kref.hamming_matrix_ref(wr, ws))
    got = np.asarray(kops.hamming_matrix(wr, ws, impl="mxu", interpret=True, tile=32))
    assert np.array_equal(ref, got)


def test_bitplane_kernel_direct():
    b = 128
    wr = _random_words(64, b, seed=9)
    planes = bm.unpack_bits(wr).astype(jnp.int8)
    pc = bm.popcount_rows(wr)
    got = bitplane_hamming_pallas(planes, planes, pc, pc, tile_r=32, tile_s=32,
                                  interpret=True)
    ref = kref.hamming_matrix_ref(wr, wr)
    assert np.array_equal(np.asarray(ref), np.asarray(got))


@pytest.mark.parametrize("sim,tau", [("jaccard", 0.6), ("jaccard", 0.9),
                                     ("cosine", 0.8), ("dice", 0.7),
                                     ("overlap", 5.0)])
@pytest.mark.parametrize("self_join", [True, False])
def test_candidate_kernel_matches_ref(sim, tau, self_join):
    b = 64
    words, lens = _random_collection_words(96, b, seed=11)
    ref = np.asarray(kref.candidate_matrix_ref(
        words, words, lens, lens, sim=sim, tau=tau, self_join=self_join, cutoff=30))
    got = np.asarray(kops.candidate_matrix(
        words, words, lens, lens, sim=sim, tau=tau, self_join=self_join,
        cutoff=30, impl="swar", interpret=True, tile=32))
    assert np.array_equal(ref, got), (sim, tau, self_join)


def test_candidate_kernel_never_false_negative():
    """Pairs that are truly similar must always survive the fused kernel."""
    from repro.core import bounds, verify
    b = 64
    words, lens = _random_collection_words(64, b, seed=13)
    cand = np.asarray(kops.candidate_matrix(
        words, words, lens, lens, sim="jaccard", tau=0.5, self_join=True,
        impl="swar", interpret=True, tile=32))
    # ground truth from the ref hamming bound is conservative by Theorem 1 —
    # spot-check against the analytical requirement instead
    ham = np.asarray(kref.hamming_matrix_ref(words, words))
    l = np.asarray(lens)
    ub = np.minimum((l[:, None] + l[None, :] - ham) // 2,
                    np.minimum(l[:, None], l[None, :]))
    need = 0.5 / 1.5 * (l[:, None] + l[None, :])
    truly = ub >= need
    iu = np.triu_indices(len(l), k=1)
    assert (cand[iu] == truly[iu]).all()


def test_impl_dispatch_cpu_defaults_to_ref():
    assert kops.resolve_impl("auto", 64) == "ref"
    assert kops.resolve_impl("swar", 64) == "swar"


def test_pack_unpack_roundtrip():
    w = _random_words(17, 256, seed=21)
    assert np.array_equal(np.asarray(bm.pack_bits(bm.unpack_bits(w))), np.asarray(w))


def test_popcount32_exact():
    rng = np.random.default_rng(0)
    v = rng.integers(0, 2 ** 32, size=1000, dtype=np.uint32)
    got = np.asarray(bm.popcount32(jnp.asarray(v)))
    ref = np.array([bin(x).count("1") for x in v], dtype=np.uint32)
    assert np.array_equal(got, ref)


@pytest.mark.parametrize("b,sq,sk,h,kv,d,causal", [
    (2, 64, 64, 4, 2, 16, True),
    (1, 128, 128, 6, 3, 32, True),
    (2, 32, 64, 4, 4, 16, False),
])
def test_flash_kernel_matches_jnp(b, sq, sk, h, kv, d, causal):
    """Fused Pallas flash-attention fwd vs the custom-VJP jnp path."""
    from repro.kernels.flash_attention import flash_attention_fwd_pallas
    from repro.models.layers import flash_attention

    rng = np.random.default_rng(b + sq)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, kv, d)), jnp.float32)
    got = flash_attention_fwd_pallas(q, k, v, causal=causal, q_chunk=16,
                                     kv_chunk=16, interpret=True)
    ref = flash_attention(q, k, v, causal=causal, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
