"""Property tests for Theorem 1 — the exactness core of the paper.

For every bitmap generation method and any pair of sets, the Eq. 2 upper
bound must dominate the true overlap (no false negatives, ever)."""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no pip index — seeded fallback
    from _propstrat import given, settings, strategies as st

from repro.core import bitmap as bm
from repro.core import bounds
from repro.core.constants import BITMAP_METHODS, PAD_TOKEN, SIM_FUNCTIONS

_LUT = np.unpackbits(np.arange(256, dtype=np.uint8)[:, None], axis=1).sum(1)


def _pair_to_padded(r, s):
    r = sorted(set(r))
    s = sorted(set(s))
    l = max(len(r), len(s), 1)
    toks = np.full((2, l), PAD_TOKEN, dtype=np.int32)
    toks[0, : len(r)] = r
    toks[1, : len(s)] = s
    return toks, np.array([len(r), len(s)], dtype=np.int32)


sets_strategy = st.lists(st.integers(0, 500), min_size=0, max_size=60)


@pytest.mark.parametrize("method", BITMAP_METHODS)
@pytest.mark.parametrize("b", [32, 64, 128])
@settings(max_examples=30, deadline=None)
@given(r=sets_strategy, s=sets_strategy)
def test_eq2_upper_bound_holds(method, b, r, s):
    toks, lens = _pair_to_padded(r, s)
    words = np.asarray(bm.generate_bitmaps(
        jnp.asarray(toks), jnp.asarray(lens), b, method=method))
    ham = int(_LUT[(words[0] ^ words[1]).view(np.uint8)].sum())
    ub = bounds.overlap_upper_bound(int(lens[0]), int(lens[1]), ham)
    true_overlap = len(set(r) & set(s))
    assert true_overlap <= ub, (method, b, true_overlap, ub)


@settings(max_examples=50, deadline=None)
@given(r=sets_strategy.filter(lambda x: len(set(x)) >= 1),
       s=sets_strategy.filter(lambda x: len(set(x)) >= 1),
       sim=st.sampled_from(["jaccard", "cosine", "dice"]),
       tau=st.floats(0.1, 0.95))
def test_equivalent_overlap_matches_similarity(r, s, sim, tau):
    """o >= equivalent_overlap  <=>  sim >= tau (Table 1)."""
    rs, ss = set(r), set(s)
    o = len(rs & ss)
    lr, ls = len(rs), len(ss)
    simval = float(bounds.similarity(sim, o, lr, ls))
    need = float(bounds.equivalent_overlap(sim, tau, lr, ls))
    assert (simval >= tau - 1e-12) == (o >= need - 1e-9), (o, need, simval, tau)


@settings(max_examples=50, deadline=None)
@given(r=sets_strategy.filter(lambda x: len(set(x)) >= 1),
       s=sets_strategy.filter(lambda x: len(set(x)) >= 1),
       sim=st.sampled_from(["jaccard", "cosine", "dice"]),
       tau=st.floats(0.1, 0.95))
def test_length_filter_never_prunes_similar(r, s, sim, tau):
    rs, ss = set(r), set(s)
    o = len(rs & ss)
    lr, ls = len(rs), len(ss)
    if float(bounds.similarity(sim, o, lr, ls)) >= tau:
        lo, hi = bounds.length_bounds(sim, tau, lr)
        assert lo - 1e-9 <= ls <= hi + 1e-9


@settings(max_examples=50, deadline=None)
@given(r=sets_strategy.filter(lambda x: len(set(x)) >= 2),
       sim=st.sampled_from(["overlap", "jaccard", "cosine", "dice"]),
       tau=st.floats(0.2, 0.95))
def test_prefix_length_bounds(r, sim, tau):
    n = len(set(r))
    p = int(bounds.prefix_length(sim, tau if sim != "overlap" else max(1, int(tau * n)), n))
    assert 0 <= p <= n


def test_required_overlap_roundtrips_all_sim_constants():
    """Every sim-name constant must be accepted by the shared float32 helper
    (the single deduplicated copy of the Table 1 formula used by the Pallas
    kernels, the jnp oracles and the ring join) and agree with the
    dtype-polymorphic :func:`bounds.equivalent_overlap`."""
    lr64 = np.array([1, 3, 7, 40, 200], dtype=np.int64)
    ls64 = np.array([2, 3, 9, 17, 333], dtype=np.int64)
    lr = jnp.asarray(lr64, jnp.int32)
    ls = jnp.asarray(ls64, jnp.int32)
    for sim in SIM_FUNCTIONS:
        for tau in (0.5, 0.8, 3.0):
            got = np.asarray(bounds.required_overlap(sim, tau, lr, ls))
            want = bounds.equivalent_overlap(sim, tau, lr64, ls64)
            assert got.dtype == np.float32
            np.testing.assert_allclose(got, want, rtol=1e-6)
    with pytest.raises(ValueError):
        bounds.required_overlap("not-a-sim", 0.5, lr, ls)


def test_required_overlap_is_the_single_shared_copy():
    """The kernel oracle alias must be the bounds helper itself — no drifting
    duplicate formulas (the old `_required_overlap`/`_need` copies)."""
    from repro.kernels import ref as kref
    assert kref.required_overlap_ref is bounds.required_overlap
    from repro.core import join as join_mod
    from repro.kernels import bitmap_filter as bf_mod
    assert not hasattr(join_mod, "_need")
    assert not hasattr(bf_mod, "_required_overlap")


@settings(max_examples=40, deadline=None)
@given(sim=st.sampled_from(["overlap", "jaccard", "cosine", "dice"]),
       tau=st.floats(0.2, 0.95), lr=st.integers(0, 300), ls=st.integers(0, 300))
def test_length_window_int_equals_float_window(sim, tau, lr, ls):
    """ceil/floor integer bounds are exactly the real-valued Table 2 window
    for integer |s| — the identity the device-resident path relies on."""
    if sim == "overlap":
        tau = float(max(1, int(tau * 10)))
    lo_f, hi_f = bounds.length_bounds(sim, tau, np.float64(max(lr, 1)))
    lo_i, hi_i = bounds.length_window_int(sim, tau, np.array([max(lr, 1)]))
    assert ((ls >= lo_f) and (ls <= hi_f)) == ((ls >= lo_i[0]) and (ls <= hi_i[0]))
