"""Property tests for Theorem 1 — the exactness core of the paper.

For every bitmap generation method and any pair of sets, the Eq. 2 upper
bound must dominate the true overlap (no false negatives, ever)."""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no pip index — seeded fallback
    from _propstrat import given, settings, strategies as st

from repro.core import bitmap as bm
from repro.core import bounds
from repro.core.constants import BITMAP_METHODS, PAD_TOKEN

_LUT = np.unpackbits(np.arange(256, dtype=np.uint8)[:, None], axis=1).sum(1)


def _pair_to_padded(r, s):
    r = sorted(set(r))
    s = sorted(set(s))
    l = max(len(r), len(s), 1)
    toks = np.full((2, l), PAD_TOKEN, dtype=np.int32)
    toks[0, : len(r)] = r
    toks[1, : len(s)] = s
    return toks, np.array([len(r), len(s)], dtype=np.int32)


sets_strategy = st.lists(st.integers(0, 500), min_size=0, max_size=60)


@pytest.mark.parametrize("method", BITMAP_METHODS)
@pytest.mark.parametrize("b", [32, 64, 128])
@settings(max_examples=30, deadline=None)
@given(r=sets_strategy, s=sets_strategy)
def test_eq2_upper_bound_holds(method, b, r, s):
    toks, lens = _pair_to_padded(r, s)
    words = np.asarray(bm.generate_bitmaps(
        jnp.asarray(toks), jnp.asarray(lens), b, method=method))
    ham = int(_LUT[(words[0] ^ words[1]).view(np.uint8)].sum())
    ub = bounds.overlap_upper_bound(int(lens[0]), int(lens[1]), ham)
    true_overlap = len(set(r) & set(s))
    assert true_overlap <= ub, (method, b, true_overlap, ub)


@settings(max_examples=50, deadline=None)
@given(r=sets_strategy.filter(lambda x: len(set(x)) >= 1),
       s=sets_strategy.filter(lambda x: len(set(x)) >= 1),
       sim=st.sampled_from(["jaccard", "cosine", "dice"]),
       tau=st.floats(0.1, 0.95))
def test_equivalent_overlap_matches_similarity(r, s, sim, tau):
    """o >= equivalent_overlap  <=>  sim >= tau (Table 1)."""
    rs, ss = set(r), set(s)
    o = len(rs & ss)
    lr, ls = len(rs), len(ss)
    simval = float(bounds.similarity(sim, o, lr, ls))
    need = float(bounds.equivalent_overlap(sim, tau, lr, ls))
    assert (simval >= tau - 1e-12) == (o >= need - 1e-9), (o, need, simval, tau)


@settings(max_examples=50, deadline=None)
@given(r=sets_strategy.filter(lambda x: len(set(x)) >= 1),
       s=sets_strategy.filter(lambda x: len(set(x)) >= 1),
       sim=st.sampled_from(["jaccard", "cosine", "dice"]),
       tau=st.floats(0.1, 0.95))
def test_length_filter_never_prunes_similar(r, s, sim, tau):
    rs, ss = set(r), set(s)
    o = len(rs & ss)
    lr, ls = len(rs), len(ss)
    if float(bounds.similarity(sim, o, lr, ls)) >= tau:
        lo, hi = bounds.length_bounds(sim, tau, lr)
        assert lo - 1e-9 <= ls <= hi + 1e-9


@settings(max_examples=50, deadline=None)
@given(r=sets_strategy.filter(lambda x: len(set(x)) >= 2),
       sim=st.sampled_from(["overlap", "jaccard", "cosine", "dice"]),
       tau=st.floats(0.2, 0.95))
def test_prefix_length_bounds(r, sim, tau):
    n = len(set(r))
    p = int(bounds.prefix_length(sim, tau if sim != "overlap" else max(1, int(tau * n)), n))
    assert 0 <= p <= n
