"""Property tests for Theorem 1 — the exactness core of the paper.

For every bitmap generation method and any pair of sets, the Eq. 2 upper
bound must dominate the true overlap (no false negatives, ever)."""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no pip index — seeded fallback
    from _propstrat import given, settings, strategies as st

from repro.core import bitmap as bm
from repro.core import bounds
from repro.core.constants import BITMAP_METHODS, PAD_TOKEN, SIM_FUNCTIONS

_LUT = np.unpackbits(np.arange(256, dtype=np.uint8)[:, None], axis=1).sum(1)


def _pair_to_padded(r, s):
    r = sorted(set(r))
    s = sorted(set(s))
    l = max(len(r), len(s), 1)
    toks = np.full((2, l), PAD_TOKEN, dtype=np.int32)
    toks[0, : len(r)] = r
    toks[1, : len(s)] = s
    return toks, np.array([len(r), len(s)], dtype=np.int32)


sets_strategy = st.lists(st.integers(0, 500), min_size=0, max_size=60)


@pytest.mark.parametrize("method", BITMAP_METHODS)
@pytest.mark.parametrize("b", [32, 64, 128])
@settings(max_examples=30, deadline=None)
@given(r=sets_strategy, s=sets_strategy)
def test_eq2_upper_bound_holds(method, b, r, s):
    toks, lens = _pair_to_padded(r, s)
    words = np.asarray(bm.generate_bitmaps(
        jnp.asarray(toks), jnp.asarray(lens), b, method=method))
    ham = int(_LUT[(words[0] ^ words[1]).view(np.uint8)].sum())
    ub = bounds.overlap_upper_bound(int(lens[0]), int(lens[1]), ham)
    true_overlap = len(set(r) & set(s))
    assert true_overlap <= ub, (method, b, true_overlap, ub)


@settings(max_examples=50, deadline=None)
@given(r=sets_strategy.filter(lambda x: len(set(x)) >= 1),
       s=sets_strategy.filter(lambda x: len(set(x)) >= 1),
       sim=st.sampled_from(["jaccard", "cosine", "dice"]),
       tau=st.floats(0.1, 0.95))
def test_equivalent_overlap_matches_similarity(r, s, sim, tau):
    """o >= equivalent_overlap  <=>  sim >= tau (Table 1)."""
    rs, ss = set(r), set(s)
    o = len(rs & ss)
    lr, ls = len(rs), len(ss)
    simval = float(bounds.similarity(sim, o, lr, ls))
    need = float(bounds.equivalent_overlap(sim, tau, lr, ls))
    assert (simval >= tau - 1e-12) == (o >= need - 1e-9), (o, need, simval, tau)


@settings(max_examples=50, deadline=None)
@given(r=sets_strategy.filter(lambda x: len(set(x)) >= 1),
       s=sets_strategy.filter(lambda x: len(set(x)) >= 1),
       sim=st.sampled_from(["jaccard", "cosine", "dice"]),
       tau=st.floats(0.1, 0.95))
def test_length_filter_never_prunes_similar(r, s, sim, tau):
    rs, ss = set(r), set(s)
    o = len(rs & ss)
    lr, ls = len(rs), len(ss)
    if float(bounds.similarity(sim, o, lr, ls)) >= tau:
        lo, hi = bounds.length_bounds(sim, tau, lr)
        assert lo - 1e-9 <= ls <= hi + 1e-9


@settings(max_examples=50, deadline=None)
@given(r=sets_strategy.filter(lambda x: len(set(x)) >= 2),
       sim=st.sampled_from(["overlap", "jaccard", "cosine", "dice"]),
       tau=st.floats(0.2, 0.95))
def test_prefix_length_bounds(r, sim, tau):
    n = len(set(r))
    p = int(bounds.prefix_length(sim, tau if sim != "overlap" else max(1, int(tau * n)), n))
    assert 0 <= p <= n


def test_required_overlap_roundtrips_all_sim_constants():
    """Every sim-name constant must be accepted by the shared float32 helper
    (the single deduplicated copy of the Table 1 formula used by the Pallas
    kernels, the jnp oracles and the ring join) and agree with the
    dtype-polymorphic :func:`bounds.equivalent_overlap`."""
    lr64 = np.array([1, 3, 7, 40, 200], dtype=np.int64)
    ls64 = np.array([2, 3, 9, 17, 333], dtype=np.int64)
    lr = jnp.asarray(lr64, jnp.int32)
    ls = jnp.asarray(ls64, jnp.int32)
    for sim in SIM_FUNCTIONS:
        for tau in (0.5, 0.8, 3.0):
            got = np.asarray(bounds.required_overlap(sim, tau, lr, ls))
            want = bounds.equivalent_overlap(sim, tau, lr64, ls64)
            assert got.dtype == np.float32
            np.testing.assert_allclose(got, want, rtol=1e-6)
    with pytest.raises(ValueError):
        bounds.required_overlap("not-a-sim", 0.5, lr, ls)


def test_required_overlap_is_the_single_shared_copy():
    """The kernel oracle alias must be the bounds helper itself — no drifting
    duplicate formulas (the old `_required_overlap`/`_need` copies)."""
    from repro.kernels import ref as kref
    assert kref.required_overlap_ref is bounds.required_overlap
    from repro.core import join as join_mod
    from repro.kernels import bitmap_filter as bf_mod
    assert not hasattr(join_mod, "_need")
    assert not hasattr(bf_mod, "_required_overlap")


@settings(max_examples=40, deadline=None)
@given(sim=st.sampled_from(["overlap", "jaccard", "cosine", "dice"]),
       tau=st.floats(0.2, 0.95), lr=st.integers(0, 300), ls=st.integers(0, 300))
def test_length_window_int_is_exact_and_near_float_window(sim, tau, lr, ls):
    """The integer window is the verification-exact one: it admits a
    partner size iff the best achievable overlap ``min(|r|, |s|)`` reaches
    the Table 1 equivalent overlap (so the length filter can never prune a
    pair verification would accept), and it never strays more than one
    integer from the raw float Table 2 window (whose ceil/floor can drift
    off boundary values like ``5 * 0.8``)."""
    if sim == "overlap":
        tau = float(max(1, int(tau * 10)))
    lr = max(lr, 1)
    lo_i, hi_i = bounds.length_window_int(sim, tau, np.array([lr]))
    in_window = bool(lo_i[0] <= ls <= hi_i[0])
    admissible = (ls >= 1
                  and min(lr, ls) >= bounds.equivalent_overlap(sim, tau, lr, ls))
    if admissible:
        assert in_window, (sim, tau, lr, ls, lo_i, hi_i)
    lo_f, hi_f = bounds.length_bounds(sim, tau, np.float64(lr))
    in_float = (ls >= lo_f) and (ls <= hi_f)
    if in_float and ls >= 1:
        assert in_window  # only ever widened, never shrunk
    if in_window and not in_float:
        # widening is bounded by one integer on each side
        assert (lo_f - 1 <= ls <= hi_f + 1)


@settings(max_examples=40, deadline=None)
@given(sim=st.sampled_from(["overlap", "jaccard", "cosine", "dice"]),
       tau=st.floats(0.2, 0.95), lr=st.integers(1, 300), ls=st.integers(0, 300))
def test_length_window_int_is_symmetric(sim, tau, lr, ls):
    """In exact arithmetic the Table 2 window is symmetric (|s| admissible
    for |r| iff |r| admissible for |s|); float rounding used to break this
    on boundaries like (4, 5) at Jaccard 0.8 — the need-corrected integer
    window must not."""
    ls = max(ls, 1)
    lo_r, hi_r = bounds.length_window_int(sim, tau, np.array([lr]))
    lo_s, hi_s = bounds.length_window_int(sim, tau, np.array([ls]))
    assert (lo_r[0] <= ls <= hi_r[0]) == (lo_s[0] <= lr <= hi_s[0]), (
        sim, tau, lr, ls, (lo_r, hi_r), (lo_s, hi_s))


def test_length_window_int_fixes_known_boundary_drift():
    """5 * 0.8 == 4.0000000000000002 in float64: the raw ceil would exclude
    |r| = 4 from |s| = 5's window at Jaccard 0.8 while verification accepts
    the (4 ⊂ 5) pair — the regression the 20k indexed-vs-blocked mismatch
    exposed."""
    lo, hi = bounds.length_window_int("jaccard", 0.8, np.array([5]))
    assert lo[0] <= 4 <= hi[0]


@settings(max_examples=40, deadline=None)
@given(sim=st.sampled_from(["overlap", "jaccard", "cosine", "dice"]),
       tau=st.floats(0.2, 0.95), lr=st.integers(1, 120), ls=st.integers(1, 120))
def test_min_overlap_table_matches_oracle_acceptance(sim, tau, lr, ls):
    """The gatherable integer table decides ``o >= equivalent_overlap``
    bit-identically to the f64 oracle for every integer overlap — the
    contract that lets device (float32) verification agree with
    ``naive_join`` on exactly-at-threshold pairs."""
    if sim == "overlap":
        tau = float(max(1, int(tau * 10)))
    tab = bounds.min_overlap_table(sim, tau, 120, 120)
    got = int(np.asarray(bounds.min_overlap_gather(
        sim, jnp.asarray(tab), jnp.asarray([lr]), jnp.asarray([ls])))[0])
    assert got == int(bounds.min_overlap_int(sim, tau, lr, ls))
    need = float(bounds.equivalent_overlap(sim, tau, lr, ls))
    for o in range(0, min(lr, ls) + 1):
        assert (o >= got) == (o >= need), (sim, tau, lr, ls, o, got, need)


def test_required_overlap_safe_is_a_lower_bound():
    """The prune-side f32 threshold never exceeds the f64 oracle value, so
    an f32 prune is always a subset of the f64 one (keeping more is safe;
    exact verification does the rest)."""
    rng = np.random.default_rng(0)
    lr = rng.integers(1, 400, size=2000)
    ls = rng.integers(1, 400, size=2000)
    for sim in ("jaccard", "cosine", "dice", "overlap"):
        taus = (3.0, 5.0) if sim == "overlap" else (0.5, 0.8, 0.9)
        for tau in taus:
            safe = np.asarray(bounds.required_overlap_safe(
                sim, tau, jnp.asarray(lr), jnp.asarray(ls)),
                dtype=np.float64)
            exact = bounds.equivalent_overlap(sim, tau, lr.astype(np.int64),
                                              ls.astype(np.int64))
            assert np.all(safe <= exact + 1e-12), (sim, tau)


@settings(max_examples=40, deadline=None)
@given(sim=st.sampled_from(["overlap", "jaccard", "cosine", "dice"]),
       tau=st.floats(0.2, 0.95), lr=st.integers(1, 300), ls=st.integers(1, 300))
def test_filters_length_window_routes_through_int_window(sim, tau, lr, ls):
    """core/filters.length_window and length_filter_mask are thin routes to
    bounds.length_window_int — bit-identical across sims × tau, so the host
    filter path cannot drift from the integer-exact device path."""
    from repro.core import filters

    if sim == "overlap":
        tau = float(max(1, int(tau * 10)))
    lo_w, hi_w = filters.length_window(sim, tau, np.array([lr]))
    lo_b, hi_b = bounds.length_window_int(sim, tau, np.array([lr]))
    assert np.array_equal(lo_w, lo_b) and np.array_equal(hi_w, hi_b)
    mask = filters.length_filter_mask(sim, tau, np.array([lr]), np.array([ls]))
    assert bool(mask[0]) == bool(lo_b[0] <= ls <= hi_b[0])
