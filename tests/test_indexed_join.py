"""``"indexed"``-driver specifics: forced-capacity escalation, ℓ-prefix
schemas, planner/engine integration and the sub-quadratic acceptance claim.

The full sim × τ × collection-shape oracle sweep now lives in the single
conformance suite (``tests/test_driver_conformance.py``), which runs it for
every registered driver; this file keeps what is unique to the indexed
path: deliberately tiny forced capacities that overflow into the dense
escalation, the candidate-funnel shape (postings expanded ≥ candidates
generated ≥ after-bitmap ≥ verified), and the requirement that on a skewed
self-join the driver evaluates the bitmap on a small fraction of the cells
the blocked (grid) driver evaluates.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no pip index — seeded fallback
    from _propstrat import given, settings, strategies as st

from repro.core import join
from repro.core.collection import from_lists
from repro.core.engine import JoinEngine, prepare
from repro.core.plan import JoinPlan, JoinPlanner
from repro.index import indexed_bitmap_join, indexed_join_prepared

# sim × τ grid spanning the acceptance range; overlap takes absolute counts.
SIM_TAUS = ([(s, t) for s in ("jaccard", "cosine", "dice")
             for t in (0.5, 0.7, 0.85, 0.95)]
            + [("overlap", 2.0), ("overlap", 5.0)])

_PAD = 16  # fixed padded width -> one jit cache across examples
KINDS = ("uniform", "skewed", "dup_heavy")


def _collection(kind: str, seed: int, n: int = 48):
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        sets = [rng.choice(110, size=rng.integers(1, 13), replace=False).tolist()
                for _ in range(n)]
    elif kind == "skewed":
        sets = []
        for _ in range(n):
            sz = int(rng.integers(1, 13))
            toks = np.unique(np.minimum(rng.zipf(1.3, size=3 * sz + 4), 140))[:sz]
            sets.append(toks.tolist())
    elif kind == "dup_heavy":
        base = [rng.choice(110, size=rng.integers(2, 13), replace=False).tolist()
                for _ in range(max(n // 4, 1))]
        sets = []
        for _ in range(n):
            src = base[int(rng.integers(len(base)))]
            kept = [t for t in src if rng.random() > 0.15]
            sets.append(kept or src[:1])
    else:
        raise KeyError(kind)
    return from_lists(sets, pad_to=_PAD)


def _check_funnel(stats: join.JoinStats):
    """Candidates generated >= after-bitmap >= verified, ratios in range."""
    assert stats.candidates_generated == stats.total_pairs, stats
    assert (stats.verified_true <= stats.candidates
            <= stats.candidates_generated), stats
    assert 0.0 <= stats.filter_ratio <= 1.0, stats
    assert 0.0 <= stats.precision <= 1.0, stats
    assert stats.blocks_skipped <= stats.blocks_total, stats
    assert stats.overflow_blocks >= 0, stats


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000), kind=st.sampled_from(KINDS))
def test_indexed_auto_capacity_never_overflows(seed, kind):
    """Funnel shape specific to auto-sizing: the prepass-sized capacity must
    never overflow and postings volume bounds the deduped candidates.  (The
    sim × τ oracle sweep itself lives in the conformance suite.)"""
    col = _collection(kind, seed)
    got, stats = indexed_bitmap_join(col, "jaccard", 0.7, b=32,
                                     probe_block=16, return_stats=True)
    assert np.array_equal(join.naive_join(col, "jaccard", 0.7), got)
    _check_funnel(stats)
    assert stats.postings_expanded >= stats.candidates_generated
    assert stats.overflow_blocks == 0  # prepass-sized capacity never overflows


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), simtau=st.sampled_from(SIM_TAUS),
       cap=st.sampled_from((1, 2, 4, 8)))
def test_indexed_forced_overflow_escalates_exactly(seed, simtau, cap):
    """Deliberately tiny capacities: chunks whose expansion overflows must
    be escalated to the dense fallback without losing a single pair."""
    sim, tau = simtau
    col = _collection("dup_heavy", seed)
    oracle = join.naive_join(col, sim, tau)
    got, stats = indexed_bitmap_join(col, sim, tau, b=32, probe_block=16,
                                     capacity=cap, return_stats=True)
    assert np.array_equal(oracle, got), (sim, tau, cap, len(oracle), len(got))
    _check_funnel(stats)
    # Pigeonhole: more expanded entries than cap × chunks means at least one
    # chunk overflowed — the escalation it claims must be recorded.
    active = stats.blocks_total - stats.blocks_skipped
    if stats.postings_expanded > cap * active:
        assert stats.overflow_blocks > 0, stats


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), simtau=st.sampled_from(SIM_TAUS))
def test_indexed_rs_forced_capacity_exact(seed, simtau):
    """R×S with a deliberately small forced capacity: the escalation path
    must stay exact for two-collection joins too.  (The unforced R×S oracle
    sweep lives in the conformance suite.)"""
    sim, tau = simtau
    rng = np.random.default_rng(seed)
    col_r = _collection("uniform", seed, n=48)
    sets_s = [rng.choice(110, size=rng.integers(1, 13), replace=False).tolist()
              for _ in range(32)]
    for k in range(4):  # cross-collection duplicates -> non-trivial joins
        sets_s[k] = list(col_r.row(3 * k))
    col_s = from_lists(sets_s, pad_to=_PAD)
    oracle = join.naive_join(col_r, col_s, sim, tau)
    got, stats = indexed_bitmap_join(col_r, col_s, sim, tau, b=32,
                                     probe_block=16, capacity=4,
                                     return_stats=True)
    assert np.array_equal(oracle, got), (sim, tau, len(oracle), len(got))
    _check_funnel(stats)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), ell=st.sampled_from((2, 3)))
def test_indexed_ell_prefix_index_is_exact(seed, ell):
    """An ℓ-prefix index is a superset of the 1-prefix one — results must
    be identical for any ℓ."""
    col = _collection("dup_heavy", seed)
    oracle = join.naive_join(col, "jaccard", 0.7)
    got = indexed_bitmap_join(col, "jaccard", 0.7, b=32, probe_block=16,
                              ell=ell)
    assert np.array_equal(oracle, got)


def test_indexed_same_prepared_object_is_full_cross_product():
    col = _collection("dup_heavy", 5)
    prep = prepare(col)
    oracle = join.naive_join(col, col, "jaccard", 0.6)  # includes diagonal
    got = indexed_join_prepared(prep, prep, sim="jaccard", tau=0.6, b=32,
                                probe_block=16)
    assert np.array_equal(oracle, got)


def test_indexed_empty_and_tiny_inputs():
    empty = from_lists([[]], pad_to=_PAD)
    assert len(indexed_bitmap_join(empty, "jaccard", 0.8, b=32)) == 0
    one = from_lists([[1, 2, 3]], pad_to=_PAD)
    assert len(indexed_bitmap_join(one, "jaccard", 0.8, b=32)) == 0
    two = from_lists([[1, 2, 3], [1, 2, 3]], pad_to=_PAD)
    pairs = indexed_bitmap_join(two, "jaccard", 0.8, b=32)
    assert np.array_equal(pairs, np.array([[0, 1]]))


# ---------------------------------------------------------------------------
# Planner + engine integration
# ---------------------------------------------------------------------------

def test_planner_picks_indexed_above_cells_threshold():
    mk = lambda **kw: JoinPlanner().plan(backend="cpu", n_devices=1, **kw)
    big = mk(sim="jaccard", tau=0.8, n_r=20_000)
    assert big.driver == "indexed"
    assert any("indexed" in r for r in big.reasons)
    # below the cells floor, low tau, absolute-overlap sim: all stay blocked
    assert mk(sim="jaccard", tau=0.8, n_r=5000).driver == "blocked"
    assert mk(sim="jaccard", tau=0.4, n_r=20_000).driver == "blocked"
    assert mk(sim="overlap", tau=5.0, n_r=20_000).driver == "blocked"
    with pytest.raises(ValueError, match="ell"):
        JoinPlan(driver="indexed", sim="jaccard", tau=0.8, ell=0)


def test_planner_multi_device_sharded_indexed_vs_ring():
    """On a mesh, the same indexed-cells / τ conditions that justify the
    index on one device pick the sharded-indexed driver; otherwise ring."""
    mk = lambda **kw: JoinPlanner().plan(backend="cpu", n_devices=8, **kw)
    sharded = mk(sim="jaccard", tau=0.8, n_r=20_000)
    assert sharded.driver == "sharded-indexed"
    assert any("sharded-indexed" in r for r in sharded.reasons)
    # low tau, small grid, absolute-overlap sim: the ring sweep still wins
    assert mk(sim="jaccard", tau=0.4, n_r=20_000).driver == "ring"
    assert mk(sim="jaccard", tau=0.8, n_r=2_000).driver == "ring"
    assert mk(sim="overlap", tau=5.0, n_r=20_000).driver == "ring"


def test_engine_executes_indexed_plan_with_cached_postings():
    rng = np.random.default_rng(17)
    corpus = _collection("dup_heavy", 17, n=80)
    sets = [list(corpus.row(2 * k)) for k in range(8)]
    sets += [rng.choice(110, size=rng.integers(1, 13), replace=False).tolist()
             for _ in range(24)]
    batch = from_lists(sets, pad_to=_PAD)
    plan = JoinPlan(driver="indexed", sim="jaccard", tau=0.7, b=32, block=16)
    engine = JoinEngine(corpus, "jaccard", 0.7, plan=plan)
    pairs1, stats1 = engine.probe(batch)
    oracle = join.naive_join(corpus, batch, "jaccard", 0.7)
    assert np.array_equal(pairs1, oracle)
    _check_funnel(stats1)
    builds = engine.prepared.build_counts()
    assert builds["postings"] == 1 and builds["bitmap"] == 1
    # second probe: postings CSR, bitmap words and sort all reused
    pairs2, _ = engine.probe(batch)
    assert np.array_equal(pairs2, oracle)
    assert engine.prepared.build_counts() == builds
    # self-join through the same engine plan (first use of the corpus-side
    # length window; postings/bitmap/sort still come from the caches)
    self_pairs = engine.self_join()
    assert np.array_equal(self_pairs, join.naive_join(corpus, "jaccard", 0.7))
    after = engine.prepared.build_counts()
    assert {k: after[k] for k in ("sort", "bitmap", "postings")} == \
        {k: builds[k] for k in ("sort", "bitmap", "postings")}


# ---------------------------------------------------------------------------
# The sub-quadratic acceptance claim (ISSUE 4)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_indexed_evaluates_fraction_of_blocked_grid_20k():
    """On a skewed 20k-set self-join at τ = 0.8 (Jaccard), the indexed
    driver must evaluate the bitmap filter on < 20% of the |R|·|S| cells
    the blocked driver evaluates (via ``JoinStats``), while returning the
    identical verified pair set."""
    from repro.data.collections import skewed_collection

    col = skewed_collection(n_sets=20_000, avg_size=9, n_tokens=100_000,
                            seed=5)
    ipairs, istats = indexed_bitmap_join(col, "jaccard", 0.8, b=32,
                                         probe_block=4096, return_stats=True)
    bpairs, bstats = join.blocked_bitmap_join(col, "jaccard", 0.8, b=32,
                                              block=4096, return_stats=True)
    assert np.array_equal(ipairs, bpairs)
    _check_funnel(istats)
    assert istats.overflow_blocks == 0
    # the sub-quadratic claim, with a wide margin over the 20% requirement
    assert bstats.candidates_generated > 0
    ratio = istats.candidates_generated / bstats.candidates_generated
    assert ratio < 0.2, (istats.candidates_generated,
                         bstats.candidates_generated, ratio)
