"""HLO analyzer validation: exact FLOP agreement with XLA's cost_analysis on
loop-free programs, and correct trip-count multiplication on scans."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze


def _compiled(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_flops_match_cost_analysis_loop_free():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)

    def f(x, y):
        return jnp.tanh(x @ y) @ y.T

    c = _compiled(f, a, b)
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    got = analyze(c.as_text())
    assert abs(got.flops - ca["flops"]) / ca["flops"] < 0.05, (got.flops, ca["flops"])


def test_scan_trip_count_multiplied():
    L, D = 12, 64
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((8, D), jnp.float32)

    def scan_f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, ws)[0]

    def unroll_f(ws, x):
        for i in range(L):
            x = jnp.tanh(x @ ws[i])
        return x

    cs = analyze(_compiled(scan_f, ws, x).as_text())
    cu = analyze(_compiled(unroll_f, ws, x).as_text())
    assert abs(cs.flops - cu.flops) / cu.flops < 0.02, (cs.flops, cu.flops)
    assert cs.flops == pytest.approx(2 * 8 * D * D * L, rel=0.01)


def test_nested_scan_multipliers():
    D, L1, L2 = 32, 5, 7
    x = jax.ShapeDtypeStruct((4, D), jnp.float32)
    w = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def f(x, w):
        def outer(x, _):
            def inner(x, _):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(inner, x, None, length=L2)
            return x, None
        return jax.lax.scan(outer, x, None, length=L1)[0]

    c = analyze(_compiled(f, x, w).as_text())
    assert c.flops == pytest.approx(2 * 4 * D * D * L1 * L2, rel=0.01)


def test_remat_increases_flops():
    D = 64
    x = jax.ShapeDtypeStruct((8, D), jnp.float32)
    w = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def loss(w, x, remat):
        def body(x, _):
            return jnp.tanh(x @ w), None
        f = lambda x: jax.lax.scan(jax.checkpoint(body) if remat else body,
                                   x, None, length=6)[0]
        return jnp.sum(f(x) ** 2)

    base = analyze(_compiled(lambda w, x: jax.grad(loss)(w, x, False), w, x).as_text())
    remat = analyze(_compiled(lambda w, x: jax.grad(loss)(w, x, True), w, x).as_text())
    assert remat.flops > base.flops * 1.2  # forward recompute visible
