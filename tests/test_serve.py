"""Serving-layer contract tests: coalescing exactness, entrypoint caching,
transfer pooling, and session observability.

The load-bearing property is **coalescing exactness**: any interleaving and
grouping of probe requests through ``JoinSession`` — merged fast-path
batches, sequential fallbacks, forced-capacity overflows, empty and
oversized requests — must yield, per request, a pair list and a
``JoinStats`` bit-identical to probing that request alone through
``JoinEngine.probe``.  The sweeps below sample request mixes and flush
cadences and compare every ticket against a fresh sequential oracle.
"""

import dataclasses
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no pip index — seeded fallback
    from _propstrat import given, settings, strategies as st

from repro.core import verify
from repro.core.collection import from_lists
from repro.core.engine import JoinEngine, prepare
from repro.serve import (
    EntrypointCache,
    JoinSession,
    RequestCoalescer,
    TransferPool,
    pow2_bucket,
)

SIM, TAU = "jaccard", 0.7
_PAD = 12  # fixed padded width -> stable jit/bucket shapes across examples


def _corpus(seed: int = 3, n: int = 250):
    """Dup-heavy corpus: near-copies force real pairs and, under a forced
    tiny capacity, solo-probe overflows."""
    rng = np.random.default_rng(seed)
    base = [rng.choice(140, size=rng.integers(3, 11), replace=False).tolist()
            for _ in range(30)]
    sets = []
    for _ in range(n):
        src = base[int(rng.integers(len(base)))]
        kept = [t for t in src if rng.random() > 0.2]
        sets.append(kept or src[:1])
    return from_lists(sets, pad_to=_PAD)


def _requests(seed: int, corpus_sets):
    """A mixed request stream: singletons, small batches, empties, and
    exact corpus rows (guaranteed matches)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(int(rng.integers(3, 9))):
        rows = int(rng.integers(0, 5))
        sets = []
        for _ in range(rows):
            if rng.random() < 0.5:
                sets.append(list(corpus_sets[int(rng.integers(
                    len(corpus_sets)))]))
            else:
                sz = int(rng.integers(1, 11))
                sets.append(rng.choice(140, size=sz,
                                       replace=False).tolist())
        out.append(from_lists(sets, pad_to=_PAD))
    return out


@pytest.fixture(scope="module")
def corpus():
    return _corpus()


@pytest.fixture(scope="module")
def session(corpus):
    return JoinSession(corpus, SIM, TAU, max_batch=16, max_wait=0.0)


@pytest.fixture(scope="module")
def oracle(corpus, session):
    return JoinEngine(prepare(corpus), SIM, TAU, plan=session.plan)


@pytest.fixture(scope="module")
def forced_session(corpus, session):
    # A forced tiny capacity: requests whose solo probe would overflow the
    # chunk (dense-fallback escalation) must route sequentially.
    plan = dataclasses.replace(session.plan, capacity=48)
    return JoinSession(corpus, SIM, TAU, plan=plan, max_batch=16,
                       max_wait=0.0)


@pytest.fixture(scope="module")
def forced_oracle(corpus, forced_session):
    return JoinEngine(prepare(corpus), SIM, TAU, plan=forced_session.plan)


def _assert_tickets_match_oracle(tickets, requests, oracle):
    for t, r in zip(tickets, requests):
        want_pairs, want_stats = oracle.probe(r)
        got_pairs, got_stats = t.result()
        assert np.array_equal(got_pairs, want_pairs), (
            f"pairs diverge (route={t.route}, rows={r.num_sets})")
        assert got_stats == want_stats, (
            f"stats diverge (route={t.route}, rows={r.num_sets}): "
            f"{got_stats} != {want_stats}")


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_coalescing_exactness_sweep(session, oracle, corpus, seed):
    """Any request mix/interleaving: per-request results == solo probes."""
    rng = np.random.default_rng(seed + 1)
    requests = _requests(seed, corpus.as_lists())
    tickets = []
    for r in requests:
        tickets.append(session.submit(r))
        if rng.random() < 0.35:  # sampled flush cadence -> varied groupings
            session.flush()
    session.flush()
    _assert_tickets_match_oracle(tickets, requests, oracle)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_coalescing_exactness_forced_overflow(forced_session, forced_oracle,
                                              corpus, seed):
    """Forced-capacity chunks (solo dense-fallback escalation) stay
    bit-identical — the session must route them through the engine."""
    requests = _requests(seed, corpus.as_lists())
    tickets = [forced_session.submit(r) for r in requests]
    forced_session.flush()
    _assert_tickets_match_oracle(tickets, requests, forced_oracle)


def test_forced_overflow_actually_routes_sequentially(forced_session, corpus):
    """The overflow guard must fire on this corpus (otherwise the sweep
    above never exercises the dense-fallback path)."""
    sets = corpus.as_lists()
    req = from_lists([sets[i] for i in range(8)], pad_to=_PAD)
    n_exp, _lp = forced_session._prepass(req)
    assert n_exp > 48  # the forced capacity
    t = forced_session.submit(req)
    forced_session.flush()
    assert t.route == "sequential"
    assert t.stats.overflow_blocks >= 1  # solo run escalated, and we match


def test_steady_state_zero_retraces(corpus):
    sess = JoinSession(corpus, SIM, TAU, max_batch=16, max_wait=0.0)
    stream = _requests(11, corpus.as_lists())
    for r in stream:
        sess.submit(r)
    sess.flush()
    warm = sess.entrypoints.stats()["traces"]
    for _ in range(3):  # identical replay -> identical buckets
        for r in stream:
            sess.submit(r)
        sess.flush()
    ep = sess.entrypoints.stats()
    assert ep["traces"] == warm, "entrypoints retraced at steady state"
    assert ep["max_traces_per_key"] == 1
    assert ep["hits"] >= 3


def test_warm_buckets_precompiles_ladder(corpus):
    sess = JoinSession(corpus, SIM, TAU, max_batch=16, max_wait=0.0)
    sample = [from_lists([s], pad_to=_PAD) for s in corpus.as_lists()[:8]]
    compiled = sess.warm_buckets(sample)
    assert compiled >= 1
    warm = sess.entrypoints.stats()["traces"]
    for r in sample * 4:  # any grouping of the sampled shapes
        sess.submit(r)
    sess.flush()
    assert sess.entrypoints.stats()["traces"] == warm


def test_session_probe_matches_engine_semantics(session, oracle, corpus):
    req = from_lists(corpus.as_lists()[:3], pad_to=_PAD)
    pairs, stats = session.probe(req)
    want_pairs, want_stats = oracle.probe(req)
    assert np.array_equal(pairs, want_pairs)
    assert stats == want_stats
    assert session.probe(req, return_stats=False).shape == pairs.shape


def test_session_stats_summary(session):
    s = session.stats_summary()
    for key in ("engine", "entrypoints", "transfer", "min_overlap_cache",
                "requests", "coalesced_requests", "sequential_requests",
                "coalesced_batches", "pad_overhead", "builds"):
        assert key in s, key
    assert s["builds"]["sort"] == 1 and s["builds"]["bitmap"] == 1
    assert s["requests"] == (s["coalesced_requests"]
                             + s["sequential_requests"])
    assert s["engine"]["probes"] == s["requests"]
    assert s["pad_overhead"] >= 0.0


# ---------------------------------------------------------------------------
# Component units: coalescer, entrypoint cache, transfer pool, verify cache
# ---------------------------------------------------------------------------


def _req(rows: int):
    return from_lists([[1 + i, 2 + i, 3 + i] for i in range(rows)],
                      pad_to=4)


def test_coalescer_due_policy():
    c = RequestCoalescer(max_batch=4, max_wait=1.0)
    assert not c.due(now=0.0)
    c.submit(_req(1), now=0.0)
    assert not c.due(now=0.5)      # neither full nor aged
    assert c.due(now=1.0)          # oldest hit max_wait
    c.submit(_req(3), now=0.1)
    assert c.due(now=0.2)          # full batch pending
    assert c.pending_rows == 4


def test_coalescer_drain_grouping():
    c = RequestCoalescer(max_batch=4, max_wait=0.0)
    rows = [2, 1, 2, 4, 6, 1]
    tickets = [c.submit(_req(r)) for r in rows]
    groups = c.drain()
    # FIFO first-fit: [2,1] | [2] (4 won't fit) | [4] | [6 oversized] | [1]
    got = [[t.rows for t in g] for g in groups]
    assert got == [[2, 1], [2], [4], [6], [1]]
    assert [t.seq for g in groups for t in g] == [t.seq for t in tickets]
    assert len(c) == 0 and c.drained_groups == 5


def test_coalescer_validation():
    with pytest.raises(ValueError):
        RequestCoalescer(max_batch=0)
    with pytest.raises(ValueError):
        RequestCoalescer(max_wait=-1.0)
    t = RequestCoalescer().submit(_req(1))
    with pytest.raises(RuntimeError):
        t.result()


def test_pow2_bucket():
    assert [pow2_bucket(n) for n in (0, 1, 2, 3, 5, 16, 17)] == \
        [1, 1, 2, 4, 8, 16, 32]
    assert pow2_bucket(3, floor=16) == 16
    assert pow2_bucket(100, floor=16) == 128


def test_entrypoint_cache_builds_once_and_counts():
    cache = EntrypointCache(maxsize=2)
    built = []

    def mk(key):
        def build():
            built.append(key)
            def fn():
                cache.note_trace(key)
                return key
            return fn
        return build

    a = cache.get("a", mk("a"))
    assert cache.get("a", mk("a")) is a
    assert built == ["a"]
    a(), a()
    s = cache.stats()
    assert s["traces"] == 2 and s["max_traces_per_key"] == 2
    cache.get("b", mk("b"))
    cache.get("c", mk("c"))   # evicts "a" (LRU, maxsize=2)
    s = cache.stats()
    assert s["entries"] == 2 and s["misses"] == 3 and s["hits"] == 1
    assert s["max_traces_per_key"] == 0  # eviction drops "a"'s trace count
    assert built == ["a", "b", "c"]
    cache.get("a", mk("a"))   # rebuilt after eviction
    assert built == ["a", "b", "c", "a"]


def test_transfer_pool_reuses_buffers():
    pool = TransferPool(depth=2)
    arrays = [np.arange(6, dtype=np.int32).reshape(2, 3),
              np.ones(2, dtype=np.int32)]
    for i in range(5):
        dev = pool.upload("k", [a + i for a in arrays])
        assert np.array_equal(np.asarray(dev[0]), arrays[0] + i)
    s = pool.stats()
    assert s["uploads"] == 5
    assert s["slot_builds"] == 2  # ring filled once, then reused
    assert s["buckets"] == 1
    # A signature change (the bucket widened) rebuilds the ring.
    pool.upload("k", [np.zeros((4, 3), np.int32), np.ones(4, np.int32)])
    assert pool.stats()["slot_builds"] == 3
    with pytest.raises(ValueError):
        TransferPool(depth=0)


def test_min_overlap_cache_locked_and_counted():
    verify._TABLE_CACHE.clear()
    base = verify.min_overlap_cache_stats()
    assert base["entries"] == 0

    errs = []

    def hammer():
        try:
            for i in range(20):
                verify.min_overlap_table_dev(SIM, TAU, 16 + (i % 3), 16)
        except Exception as e:  # pragma: no cover - failure capture
            errs.append(e)

    threads = [threading.Thread(target=hammer) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    s = verify.min_overlap_cache_stats()
    assert s["entries"] == 3
    assert s["hits"] + s["misses"] == 6 * 20
    assert s["misses"] >= 3
    # Same key twice -> identical device table object (cache hit).
    t1 = verify.min_overlap_table_dev(SIM, TAU, 16, 16)
    t2 = verify.min_overlap_table_dev(SIM, TAU, 16, 16)
    assert t1 is t2
