"""Interpret-mode sweeps for the compaction tile-count prepass kernel.

The Pallas kernel must agree exactly (integer counts) with the pure-jnp
oracle across word widths W ∈ {1, 4, 128}, non-multiple-of-tile NR/NS,
all-pass and all-prune tiles, and empty (length-0 padding) rows.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bitmap as bm, bounds
from repro.core.constants import PAD_TOKEN
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _padded_tokens(lengths, seed, width=24, universe=600):
    rng = np.random.default_rng(seed)
    toks = np.full((len(lengths), width), PAD_TOKEN, dtype=np.int32)
    for i, l in enumerate(lengths):
        if l:
            toks[i, :l] = np.sort(rng.choice(universe, size=l, replace=False))
    return jnp.asarray(toks)


def _words(lengths, b, seed):
    toks = _padded_tokens(lengths, seed)
    return bm.generate_bitmaps(toks, jnp.asarray(lengths), b, method="xor")


def _counts_both(lens_r, lens_s, b, *, sim="jaccard", tau=0.6, self_join=False,
                 cutoff=1 << 30, window=True, tile=32, seed=0):
    wr = _words(lens_r, b, seed)
    ws = wr if self_join else _words(lens_s, b, seed + 1)
    lo, hi = bounds.length_window_int(sim, tau, np.asarray(lens_r))
    args = (wr, ws, jnp.asarray(lens_r), jnp.asarray(lens_s),
            jnp.asarray(lo), jnp.asarray(hi))
    kw = dict(sim=sim, tau=tau, self_join=self_join, cutoff=cutoff,
              window=window, tile=tile)
    ref_out = kops.count_candidates(*args, impl="ref", **kw)
    pal_out = kops.count_candidates(*args, impl="swar", interpret=True, **kw)
    return [np.asarray(x) for x in ref_out], [np.asarray(x) for x in pal_out]


def _rand_lens(n, seed, lo=0, hi=21):
    return np.random.default_rng(seed).integers(lo, hi, size=n).astype(np.int32)


@pytest.mark.parametrize("b", [32, 128, 4096])  # W = 1, 4, 128 words
def test_count_kernel_word_widths(b):
    lens = _rand_lens(64, 3, lo=1)
    wr = _words(lens, b, 3)
    lo, hi = bounds.length_window_int("jaccard", 0.6, lens)
    args = (wr, wr, jnp.asarray(lens), jnp.asarray(lens),
            jnp.asarray(lo), jnp.asarray(hi))
    kw = dict(sim="jaccard", tau=0.6, self_join=False, window=True, tile=32)
    ref_w, ref_c = kops.count_candidates(*args, impl="ref", **kw)
    pal_w, pal_c = kops.count_candidates(*args, impl="swar", interpret=True, **kw)
    assert np.array_equal(np.asarray(ref_w), np.asarray(pal_w)), b
    assert np.array_equal(np.asarray(ref_c), np.asarray(pal_c)), b
    # identical R and S rows -> at least the 64 self-pairs are candidates
    assert np.asarray(ref_c).sum() >= 64


@pytest.mark.parametrize("nr,ns,tile", [(32, 32, 32), (33, 70, 32), (96, 64, 32),
                                        (40, 56, 8), (31, 17, 16)])
def test_count_kernel_nonmultiple_shapes(nr, ns, tile):
    """Last tiles are padded with empty rows; counts must be unaffected."""
    (wr, cr), (wp, cp) = _counts_both(_rand_lens(nr, nr, lo=1),
                                      _rand_lens(ns, ns + 1, lo=1), 64, tile=tile)
    assert wr.shape == (-(-nr // tile), -(-ns // tile))
    assert np.array_equal(wr, wp) and np.array_equal(cr, cp), (nr, ns, tile)


@pytest.mark.parametrize("self_join", [False, True])
@pytest.mark.parametrize("window", [False, True])
def test_count_kernel_masks(self_join, window):
    (wr, cr), (wp, cp) = _counts_both(
        _rand_lens(48, 9, lo=1), _rand_lens(48, 9, lo=1), 64,
        self_join=self_join, window=window, seed=9)
    assert np.array_equal(wr, wp) and np.array_equal(cr, cp)
    if self_join:  # strict upper triangle: fewer than half of all pairs
        assert wr.sum() <= 48 * 47 // 2


def test_count_kernel_all_pass_tile():
    """Identical sets at a permissive threshold: every (ordered) pair is both
    in-window and a bitmap candidate."""
    n = 64
    lens = np.full(n, 5, dtype=np.int32)
    rng = np.random.default_rng(0)
    row = np.sort(rng.choice(600, size=5, replace=False))
    toks = jnp.asarray(np.tile(np.concatenate(
        [row, np.full(19, PAD_TOKEN)]).astype(np.int32)[None], (n, 1)))
    words = bm.generate_bitmaps(toks, jnp.asarray(lens), 64, method="xor")
    lo, hi = bounds.length_window_int("jaccard", 0.5, lens)
    win_c, cand_c = kops.count_candidates(
        words, words, jnp.asarray(lens), jnp.asarray(lens),
        jnp.asarray(lo), jnp.asarray(hi),
        sim="jaccard", tau=0.5, self_join=False, impl="swar", interpret=True,
        tile=32)
    assert int(np.asarray(win_c).sum()) == n * n
    assert int(np.asarray(cand_c).sum()) == n * n


def test_count_kernel_all_prune_tile():
    """Length-incompatible sets (1 vs 20 at jaccard 0.9): the window prunes
    every pair, so both counts collapse to the diagonal-free zero."""
    lens_r = np.full(32, 1, dtype=np.int32)
    lens_s = np.full(32, 20, dtype=np.int32)
    (win_c, cand_c), (wp, cp) = _counts_both(lens_r, lens_s, 64, tau=0.9, seed=2)
    assert win_c.sum() == 0 and cand_c.sum() == 0
    assert wp.sum() == 0 and cp.sum() == 0


def test_count_kernel_empty_rows_never_count():
    """Length-0 rows (padding) contribute to neither output, wherever they
    sit in the tile grid."""
    lens_r = _rand_lens(48, 5, lo=0, hi=15)
    lens_r[::3] = 0
    lens_s = _rand_lens(48, 6, lo=0, hi=15)
    lens_s[1::4] = 0
    (wr, cr), (wp, cp) = _counts_both(lens_r, lens_s, 64, tau=0.5, seed=5)
    assert np.array_equal(wr, wp) and np.array_equal(cr, cp)
    # upper bound: only rows/cols with nonzero lengths can ever pair
    assert wr.sum() <= int((lens_r > 0).sum()) * int((lens_s > 0).sum())
    # all-empty collection: exactly zero
    zero = np.zeros(32, dtype=np.int32)
    (wz, cz), (wzp, czp) = _counts_both(zero, zero, 64, seed=7)
    assert wz.sum() == 0 and cz.sum() == 0 and wzp.sum() == 0 and czp.sum() == 0


def test_count_kernel_matches_dense_candidate_matrix():
    """The prepass totals equal the dense mask the host path would ship —
    the capacity it sizes is exact, not an estimate."""
    lens_r = _rand_lens(33, 11, lo=1)
    lens_s = _rand_lens(70, 12, lo=1)
    b = 64
    wr = _words(lens_r, b, 20)
    ws = _words(lens_s, b, 21)
    lo, hi = bounds.length_window_int("cosine", 0.7, lens_r)
    win_c, cand_c = kops.count_candidates(
        wr, ws, jnp.asarray(lens_r), jnp.asarray(lens_s),
        jnp.asarray(lo), jnp.asarray(hi),
        sim="cosine", tau=0.7, self_join=False, cutoff=18, impl="ref")
    dense = np.asarray(kref.candidate_matrix_ref(
        wr, ws, jnp.asarray(lens_r), jnp.asarray(lens_s), sim="cosine",
        tau=0.7, self_join=False, cutoff=18))
    win = ((lens_s[None, :] >= lo[:, None]) & (lens_s[None, :] <= hi[:, None])
           & (lens_r[:, None] > 0) & (lens_s[None, :] > 0))
    assert int(np.asarray(cand_c).sum()) == int((dense & win).sum())
    assert int(np.asarray(win_c).sum()) == int(win.sum())
