"""Training substrate: loss descent, grad-accum equivalence, optimizers,
gradient compression, checkpoint/restore, fault recovery, stragglers."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.shapes import demo_batch
from repro.distributed import CheckpointManager, FaultTolerantRunner, RunnerConfig
from repro.models import Model
from repro.train import OptimizerConfig, init_state, make_train_step
from repro.train import compress
from repro.train import optimizer as opt_lib


@pytest.fixture(scope="module")
def tiny():
    cfg = configs.get_reduced("smollm-135m")
    model = Model(cfg)
    opt_cfg = OptimizerConfig(learning_rate=3e-3, warmup_steps=5, decay_steps=100)
    state = init_state(model, opt_cfg, jax.random.PRNGKey(0))
    return cfg, model, opt_cfg, state


def test_loss_decreases(tiny):
    cfg, model, opt_cfg, state = tiny
    step = jax.jit(make_train_step(model, opt_cfg))
    rng = np.random.default_rng(0)
    batch = demo_batch(cfg, 8, 32, rng=rng)  # fixed batch: memorisation test
    first = last = None
    for i in range(30):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first * 0.7, (first, last)


def test_grad_accum_equivalent(tiny):
    cfg, model, opt_cfg, state = tiny
    batch = demo_batch(cfg, 8, 16)
    s1, m1 = jax.jit(make_train_step(model, opt_cfg, microbatches=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, opt_cfg, microbatches=4))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    l1 = jax.tree.leaves(s1["params"])
    l2 = jax.tree.leaves(s2["params"])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_adafactor_runs(tiny):
    cfg, model, _, _ = tiny
    opt_cfg = OptimizerConfig(name="adafactor", learning_rate=1e-2,
                              warmup_steps=2, decay_steps=50)
    state = init_state(model, opt_cfg, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(model, opt_cfg))
    batch = demo_batch(cfg, 4, 16)
    first = last = None
    for _ in range(20):
        state, metrics = step(state, batch)
        first = first if first is not None else float(metrics["loss"])
        last = float(metrics["loss"])
    assert np.isfinite(last) and last < first


def test_lr_schedule_shape():
    cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10, decay_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(opt_lib.lr_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 100, 1000)]
    assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert abs(lrs[3] - 0.1) < 1e-6
    assert abs(lrs[4] - 0.1) < 1e-6


def test_int8_quantization_unbiased():
    rng = jax.random.PRNGKey(0)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4096,)) * 0.01, jnp.float32)
    deqs = []
    for i in range(64):
        q, scale = compress.quantize_int8(x, jax.random.fold_in(rng, i))
        deqs.append(np.asarray(compress.dequantize_int8(q, scale, x.shape, jnp.float32)))
    mean = np.mean(deqs, axis=0)
    scale_mag = float(jnp.max(jnp.abs(x))) / 127
    np.testing.assert_allclose(mean, np.asarray(x), atol=scale_mag)  # unbiased
    assert np.abs(deqs[0] - np.asarray(x)).max() <= scale_mag + 1e-7  # bounded err


def test_checkpoint_roundtrip_and_gc(tmp_path, tiny):
    cfg, model, opt_cfg, state = tiny
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        mgr.save(s, state)
    assert mgr.all_steps() == [20, 30]  # gc keeps last 2
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, at = mgr.restore(shapes)
    assert at == 30
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_atomic(tmp_path, tiny):
    _, _, _, state = tiny
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(5, state)
    mgr.wait()
    assert mgr.latest_step() == 5
    # a stale .tmp dir (simulated crash) must be invisible to restore
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert mgr.latest_step() == 5


def test_fault_recovery_and_straggler(tmp_path, tiny):
    cfg, model, opt_cfg, state0 = tiny
    step_raw = jax.jit(make_train_step(model, opt_cfg))
    mgr = CheckpointManager(str(tmp_path))
    boom = {"armed": True}
    import time as _time

    def step_fn(state, batch):
        s = int(state["step"])
        if s == 7 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected device failure")
        if s == 11:
            _time.sleep(0.25)  # injected straggler
        return step_raw(state, batch)

    def make_state(_):
        return init_state(model, opt_cfg, jax.random.PRNGKey(0)), None

    def batches():
        while True:
            yield demo_batch(cfg, 4, 16)

    runner = FaultTolerantRunner(
        step_fn, make_state, batches(), mgr,
        RunnerConfig(checkpoint_every=5, async_checkpoint=False,
                     straggler_factor=2.5, straggler_window=8))
    out = runner.run(15)
    assert out["restarts"] == 1
    kinds = [e.kind for e in out["events"]]
    assert "failure" in kinds and "restore" in kinds
    assert int(out["state"]["step"]) == 15
    assert any(e.kind == "straggler" for e in out["events"])


def test_loader_deterministic_and_resumable():
    from repro.data.loader import LoaderConfig, SyntheticLMLoader

    cfg = configs.get_reduced("smollm-135m")
    l1 = SyntheticLMLoader(cfg, LoaderConfig(batch_size=2, seq_len=8, seed=7))
    b1 = [next(l1) for _ in range(3)]
    st = l1.state_dict()
    b_next = next(l1)
    l2 = SyntheticLMLoader(cfg, LoaderConfig(batch_size=2, seq_len=8, seed=7))
    l2.load_state_dict(st)
    b_resume = next(l2)
    np.testing.assert_array_equal(np.asarray(b_next["tokens"]),
                                  np.asarray(b_resume["tokens"]))
    l3 = SyntheticLMLoader(cfg, LoaderConfig(batch_size=2, seq_len=8, seed=7))
    np.testing.assert_array_equal(np.asarray(b1[0]["tokens"]),
                                  np.asarray(next(l3)["tokens"]))
