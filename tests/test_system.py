"""End-to-end behaviour tests: the full training driver (data pipeline with
dedup -> pjit step -> checkpoints -> resume), and the dedup stage feeding it."""

import os

import numpy as np
import pytest

from repro.data.collections import uniform_collection, with_duplicates
from repro.data.dedup import dedup_documents

pytestmark = pytest.mark.slow  # full training drivers; deselect with -m "not slow"


def test_end_to_end_training_driver(tmp_path):
    from repro.launch.train import train_main

    # 100 steps: short enough for the CPU smoke, long enough that the loss
    # trend dominates per-batch noise (40 steps flakes on batch jitter).
    out, history = train_main([
        "--arch", "smollm-135m", "--reduced", "--steps", "100",
        "--batch", "4", "--seq", "32", "--ckpt-every", "50",
        "--ckpt-dir", str(tmp_path), "--log-every", "5", "--lr", "3e-3",
    ])
    assert int(out["state"]["step"]) == 100
    losses = [m["loss"] for _, m in history]
    assert losses[-1] < losses[0]
    # checkpoints landed and resume works
    out2, _ = train_main([
        "--arch", "smollm-135m", "--reduced", "--steps", "110",
        "--batch", "4", "--seq", "32", "--ckpt-every", "50",
        "--ckpt-dir", str(tmp_path), "--log-every", "5",
    ])
    assert int(out2["state"]["step"]) == 110
    assert any(e.kind == "restore" for e in out2["events"])  # resumed, not retrained


def test_document_dedup_pipeline():
    docs = [
        "the quick brown fox jumps over the lazy dog",
        "the quick brown fox jumps over the lazy cat",   # near-dup of 0
        "completely different content about databases",
        "the quick brown fox jumps over the lazy dog!",  # near-dup of 0
        "exact set similarity joins with bitmap filters",
    ]
    kept, res = dedup_documents(docs, tau=0.5)
    assert len(kept) == 3
    assert docs[2] in kept and docs[4] in kept
    assert res.stats.verified_true >= 2


def test_musicgen_train_driver(tmp_path):
    """frame-input (audio) family goes through the same driver."""
    from repro.launch.train import train_main

    out, history = train_main([
        "--arch", "musicgen-medium", "--reduced", "--steps", "12",
        "--batch", "2", "--seq", "16", "--ckpt-every", "50",
        "--ckpt-dir", str(os.path.join(str(tmp_path), "mg")), "--log-every", "4",
    ])
    assert int(out["state"]["step"]) == 12
