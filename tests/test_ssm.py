"""SSD scan vs the naive sequential recurrence, chunk-size invariance, and
decode-step consistency with the full scan."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.ssm import mamba2_block, ssd_scan


def naive_ssm(x, a, bmat, cmat):
    """Sequential reference: h_t = exp(a_t) h_{t-1} + B_t x_t; y_t = C_t h_t."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    st = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    xf = np.asarray(x, np.float64)
    af = np.asarray(a, np.float64)
    bf = np.asarray(bmat, np.float64)
    cf = np.asarray(cmat, np.float64)
    for t in range(s):
        st = st * np.exp(af[:, t])[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", xf[:, t], bf[:, t])
        ys[:, t] = np.einsum("bhpn,bn->bhp", st, cf[:, t])
    return ys, st


def _rand(seed, *shape):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape) * 0.5,
                       jnp.float32)


@pytest.mark.parametrize("chunk", [2, 4, 8, 16])
def test_ssd_scan_matches_naive(chunk):
    b, s, h, p, n = 2, 16, 3, 4, 5
    x = _rand(0, b, s, h, p)
    a = -jnp.abs(_rand(1, b, s, h)) * 0.3
    bmat = _rand(2, b, s, n)
    cmat = _rand(3, b, s, n)
    y, st = ssd_scan(x, a, bmat, cmat, chunk)
    y_ref, st_ref = naive_ssm(x, a, bmat, cmat)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), st_ref, rtol=1e-4, atol=1e-4)


def test_chunk_size_invariance():
    b, s, h, p, n = 1, 32, 2, 4, 3
    x = _rand(5, b, s, h, p)
    a = -jnp.abs(_rand(6, b, s, h)) * 0.2
    bmat = _rand(7, b, s, n)
    cmat = _rand(8, b, s, n)
    y4, s4 = ssd_scan(x, a, bmat, cmat, 4)
    y16, s16 = ssd_scan(x, a, bmat, cmat, 16)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s4), np.asarray(s16), rtol=1e-4, atol=1e-4)


def test_initial_state_threading():
    b, s, h, p, n = 1, 8, 2, 3, 4
    x = _rand(9, b, 2 * s, h, p)
    a = -jnp.abs(_rand(10, b, 2 * s, h)) * 0.2
    bmat = _rand(11, b, 2 * s, n)
    cmat = _rand(12, b, 2 * s, n)
    y_full, st_full = ssd_scan(x, a, bmat, cmat, 4)
    y1, st1 = ssd_scan(x[:, :s], a[:, :s], bmat[:, :s], cmat[:, :s], 4)
    y2, st2 = ssd_scan(x[:, s:], a[:, s:], bmat[:, s:], cmat[:, s:], 4,
                       initial_state=st1)
    np.testing.assert_allclose(np.asarray(y_full[:, s:]), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_full), np.asarray(st2),
                               rtol=1e-4, atol=1e-4)


def test_mamba_block_decode_matches_scan():
    """Running the block step-by-step with the cache must equal the full
    sequence scan (last output)."""
    from repro import configs
    from repro.models import Model

    cfg = configs.get_reduced("mamba2-2.7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    blk = jax.tree.map(lambda a: a[0], params["blocks"]["mamba"])

    b, s, d = 1, 12, cfg.d_model
    x = _rand(20, b, s, d).astype(jnp.float32)
    kw = dict(d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
              chunk=4, norm_eps=cfg.norm_eps)
    y_full, _ = mamba2_block(x, blk, **kw)

    k = cfg.ssm_conv
    din, n = cfg.ssm_inner, cfg.ssm_state
    cache = {
        "conv_x": jnp.zeros((b, k - 1, din)), "conv_b": jnp.zeros((b, k - 1, n)),
        "conv_c": jnp.zeros((b, k - 1, n)),
        "ssm": jnp.zeros((b, cfg.ssm_heads, cfg.ssm_head_dim, n)),
    }
    outs = []
    for t in range(s):
        y_t, cache = mamba2_block(x[:, t:t + 1], blk, cache=cache, **kw)
        outs.append(np.asarray(y_t[:, 0], np.float32))
    y_step = np.stack(outs, axis=1)
    np.testing.assert_allclose(y_step, np.asarray(y_full, np.float32),
                               rtol=2e-3, atol=2e-3)
