"""The postings subsystem's building blocks: CSR compilation and the
candidate-generation kernels.

* ``build_postings`` must compile exactly the CPU algorithms' inverted
  index (``cpu_algos._build_prefix_index``) into CSR form — same tokens,
  same (set, position) entries, same per-token order — plus the invariants
  the device path relies on (dense frequency-ordered ids, non-decreasing
  composite window key).
* The Pallas kernels (``entry_filter`` / ``pair_verdict``) must agree
  bit-for-bit with the pure-jnp oracles in ``repro.kernels.ref`` (interpret
  mode on CPU), and ``pair_verdict`` with the dense
  ``candidate_matrix_ref``'s diagonal.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import cpu_algos
from repro.core.collection import from_lists
from repro.core.engine import prepare
from repro.index.postings import build_postings
from repro.kernels import ops as kops
from repro.kernels import ref


def _collection(seed: int, n: int = 48, universe: int = 110):
    rng = np.random.default_rng(seed)
    sets = [rng.choice(universe, size=rng.integers(1, 13), replace=False).tolist()
            for _ in range(n)]
    return from_lists(sets, pad_to=16)


# ---------------------------------------------------------------------------
# CSR compilation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sim,tau", [("jaccard", 0.8), ("cosine", 0.6),
                                     ("dice", 0.75), ("overlap", 3.0)])
@pytest.mark.parametrize("ell", [1, 3])
def test_csr_matches_cpu_prefix_index(sim, tau, ell):
    prep = prepare(_collection(1))
    post = prep.postings(sim, tau, ell)
    want = cpu_algos._build_prefix_index(prep.sorted_collection, sim, tau,
                                         ell=ell)
    got = post.as_dict()
    # Same tokens, same entries, same (ascending set id) order per token.
    assert got == {t: entries for t, entries in want.items() if entries}
    assert post.num_postings == sum(len(v) for v in want.values())


def test_csr_invariants_and_frequency_order():
    prep = prepare(_collection(2))
    post = prep.postings("jaccard", 0.8)
    # vocab is value-sorted, ids are a permutation.
    assert np.all(np.diff(post.vocab) > 0)
    assert sorted(post.vocab_tid.tolist()) == list(range(post.num_tokens))
    # dense ids are frequency-ordered: ascending (count, token value).
    counts = np.diff(post.starts)
    by_id_counts = counts  # starts is already laid out by dense id
    order_tokens = np.empty(post.num_tokens, dtype=np.int64)
    order_tokens[post.vocab_tid] = post.vocab
    keys = list(zip(by_id_counts.tolist(), order_tokens.tolist()))
    assert keys == sorted(keys)
    # postings inside each token's row are set-id (== length) sorted.
    for tid in range(post.num_tokens):
        sl = slice(int(post.starts[tid]), int(post.starts[tid + 1]))
        assert np.all(np.diff(post.post_set[sl]) > 0)
        assert np.all(np.diff(post.post_len[sl]) >= 0)
    # the composite window key is globally non-decreasing.
    assert np.all(np.diff(post.post_key) >= 0)
    # post_len really is lengths[post_set].
    assert np.array_equal(post.post_len, prep.lengths[post.post_set])


def test_postings_cached_per_key_on_prepared():
    prep = prepare(_collection(3))
    p1 = prep.postings("jaccard", 0.8)
    p2 = prep.postings("jaccard", 0.8)
    assert p1 is p2
    assert prep.builds["postings"] == 1
    prep.postings("jaccard", 0.8, ell=2)
    prep.postings("cosine", 0.8)
    assert prep.builds["postings"] == 3
    # device arrays are cached on the artifact too
    d1 = p1.device_arrays()
    assert p1.device_arrays() is d1


def test_empty_and_degenerate_collections():
    empty = from_lists([[]], pad_to=4)
    post = build_postings(prepare(empty), "jaccard", 0.8)
    assert post.num_postings == 0 and post.num_tokens == 0
    single = from_lists([[5, 9]], pad_to=4)
    post = build_postings(prepare(single), "jaccard", 0.8)
    assert post.num_postings >= 1
    assert post.as_dict()[5][0] == (0, 0)


# ---------------------------------------------------------------------------
# Kernels vs oracles (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g", [5, 100, 1024, 2500])
@pytest.mark.parametrize("w", [1, 4])
@pytest.mark.parametrize("sim,tau", [("jaccard", 0.7), ("overlap", 3.0)])
def test_pair_verdict_kernel_matches_ref(g, w, sim, tau):
    rng = np.random.default_rng(g * w)
    wr = jnp.asarray(rng.integers(0, 2**32, size=(g, w), dtype=np.uint32))
    ws = jnp.asarray(rng.integers(0, 2**32, size=(g, w), dtype=np.uint32))
    lr = jnp.asarray(rng.integers(0, 20, size=g, dtype=np.int32))
    ls = jnp.asarray(rng.integers(0, 20, size=g, dtype=np.int32))
    want = ref.pair_verdict_ref(wr, ws, lr, ls, sim=sim, tau=tau, cutoff=12)
    got = kops.pair_verdict(wr, ws, lr, ls, sim=sim, tau=tau, cutoff=12,
                            impl="swar", interpret=True)
    assert np.array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("impl", ["swar_tiled", "mxu", "ref_mxu"])
@pytest.mark.parametrize("g", [5, 2500])
@pytest.mark.parametrize("w", [1, 4])
@pytest.mark.parametrize("sim,tau", [("jaccard", 0.7), ("cosine", 0.6),
                                     ("dice", 0.75), ("overlap", 3.0)])
def test_pair_verdict_new_impls_match_ref(impl, g, w, sim, tau):
    """The candidate-major tiled kernel and the batched bit-plane (MXU)
    kernel/oracle are bit-identical to ref on odd and padded shapes."""
    rng = np.random.default_rng(g * w + len(impl))
    wr = jnp.asarray(rng.integers(0, 2**32, size=(g, w), dtype=np.uint32))
    ws = jnp.asarray(rng.integers(0, 2**32, size=(g, w), dtype=np.uint32))
    lr = jnp.asarray(rng.integers(0, 20, size=g, dtype=np.int32))
    ls = jnp.asarray(rng.integers(0, 20, size=g, dtype=np.int32))
    want = ref.pair_verdict_ref(wr, ws, lr, ls, sim=sim, tau=tau, cutoff=12)
    got = kops.pair_verdict(wr, ws, lr, ls, sim=sim, tau=tau, cutoff=12,
                            impl=impl, interpret=True)
    assert np.array_equal(np.asarray(want), np.asarray(got)), impl


def test_pair_verdict_tile_multiple_no_padding():
    """Exact tile-multiple G exercises the no-pad path of every impl."""
    rng = np.random.default_rng(3)
    g, w = 2048, 2
    wr = jnp.asarray(rng.integers(0, 2**32, size=(g, w), dtype=np.uint32))
    ws = jnp.asarray(rng.integers(0, 2**32, size=(g, w), dtype=np.uint32))
    lr = jnp.asarray(rng.integers(0, 30, size=g, dtype=np.int32))
    ls = jnp.asarray(rng.integers(0, 30, size=g, dtype=np.int32))
    want = np.asarray(ref.pair_verdict_ref(wr, ws, lr, ls, sim="jaccard",
                                           tau=0.8, cutoff=20))
    for impl in ("swar", "swar_tiled", "mxu", "ref_mxu"):
        got = np.asarray(kops.pair_verdict(
            wr, ws, lr, ls, sim="jaccard", tau=0.8, cutoff=20, impl=impl,
            interpret=True))
        assert np.array_equal(want, got), impl


def test_bitplane_pair_hamming_ref_matches_swar():
    from repro.core import bitmap as bm
    rng = np.random.default_rng(5)
    g, w = 333, 4
    wr = jnp.asarray(rng.integers(0, 2**32, size=(g, w), dtype=np.uint32))
    ws = jnp.asarray(rng.integers(0, 2**32, size=(g, w), dtype=np.uint32))
    want = np.asarray(jnp.sum(bm.popcount32(wr ^ ws).astype(jnp.int32), axis=-1))
    got = np.asarray(ref.bitplane_pair_hamming_ref(
        bm.unpack_bits(wr).astype(jnp.int8), bm.unpack_bits(ws).astype(jnp.int8),
        bm.popcount_rows(wr), bm.popcount_rows(ws)))
    assert np.array_equal(want, got)


def test_pairwise_impl_resolution():
    """auto resolves per backend; entry_filter maps mxu impls to elementwise
    equivalents (it has no bitmap words); explicit impls pass through."""
    assert kops._resolve_pairwise_impl("auto", 1024) == "ref"  # CPU container
    assert kops._resolve_pairwise_impl("mxu", 64) == "mxu"     # no demotion
    assert kops._resolve_pairwise_impl("swar_tiled", 64) == "swar_tiled"
    assert kops._resolve_entry_impl("mxu") == "swar"
    assert kops._resolve_entry_impl("ref_mxu") == "ref"
    assert kops._resolve_entry_impl("swar_tiled") == "swar"
    assert kops._resolve_entry_impl("auto") == "ref"


@pytest.mark.parametrize("impl", ["ref_mxu", "swar_tiled", "mxu"])
def test_indexed_driver_conformant_with_new_impls(impl):
    """Driver-level gate: the indexed join returns oracle-identical pairs
    with every pairwise verdict formulation (interpret mode on CPU)."""
    from repro.core.join import naive_join
    from repro.index import indexed_bitmap_join

    col = _collection(7, n=60, universe=90)
    want = naive_join(col, "jaccard", 0.6)
    got = indexed_bitmap_join(col, "jaccard", 0.6, impl=impl,
                              probe_block=64)
    assert np.array_equal(np.asarray(want), np.asarray(got)), impl


def test_pair_verdict_matches_candidate_matrix_diagonal():
    rng = np.random.default_rng(9)
    n, w = 64, 2
    wr = jnp.asarray(rng.integers(0, 2**32, size=(n, w), dtype=np.uint32))
    ws = jnp.asarray(rng.integers(0, 2**32, size=(n, w), dtype=np.uint32))
    lr = jnp.asarray(rng.integers(0, 16, size=n, dtype=np.int32))
    ls = jnp.asarray(rng.integers(0, 16, size=n, dtype=np.int32))
    dense = ref.candidate_matrix_ref(wr, ws, lr, ls, sim="jaccard", tau=0.7,
                                     self_join=False, cutoff=10)
    pairwise = ref.pair_verdict_ref(wr, ws, lr, ls, sim="jaccard", tau=0.7,
                                    cutoff=10)
    assert np.array_equal(np.asarray(jnp.diagonal(dense)),
                          np.asarray(pairwise))


@pytest.mark.parametrize("g", [64, 1000, 3000])
@pytest.mark.parametrize("self_join", [False, True])
def test_entry_filter_kernel_matches_ref(g, self_join):
    rng = np.random.default_rng(g)
    args = dict(
        len_r=jnp.asarray(rng.integers(0, 16, size=g, dtype=np.int32)),
        pos_r=jnp.asarray(rng.integers(0, 8, size=g, dtype=np.int32)),
        len_s=jnp.asarray(rng.integers(0, 16, size=g, dtype=np.int32)),
        pos_s=jnp.asarray(rng.integers(0, 8, size=g, dtype=np.int32)),
        lo=jnp.asarray(rng.integers(0, 10, size=g, dtype=np.int32)),
        hi=jnp.asarray(rng.integers(5, 20, size=g, dtype=np.int32)),
        idx_r=jnp.asarray(rng.integers(0, 50, size=g, dtype=np.int32)),
        idx_s=jnp.asarray(rng.integers(0, 50, size=g, dtype=np.int32)),
    )
    valid = jnp.asarray(rng.random(g) > 0.2)
    for sim, tau in [("jaccard", 0.8), ("dice", 0.6)]:
        want = ref.entry_filter_ref(*args.values(), valid, sim=sim, tau=tau,
                                    self_join=self_join)
        got = kops.entry_filter(*args.values(), valid, sim=sim, tau=tau,
                                self_join=self_join, impl="swar",
                                interpret=True)
        assert np.array_equal(np.asarray(want), np.asarray(got))


def test_entry_filter_respects_each_filter():
    """Hand-built cases: each admission condition prunes independently."""
    one = lambda v: jnp.asarray([v], dtype=jnp.int32)
    t = jnp.asarray([True])
    base = dict(len_r=one(10), pos_r=one(0), len_s=one(10), pos_s=one(0),
                lo=one(8), hi=one(12), idx_r=one(3), idx_s=one(7))

    def run(sim="jaccard", tau=0.8, self_join=False, valid=t, **over):
        kw = {**base, **{k: one(v) for k, v in over.items()}}
        return bool(np.asarray(ref.entry_filter_ref(
            *kw.values(), valid, sim=sim, tau=tau, self_join=self_join))[0])

    assert run()                                  # everything admissible
    assert not run(valid=jnp.asarray([False]))    # padding slot
    assert not run(len_r=0)                       # empty index set
    assert not run(len_r=7)                       # below the length window
    assert not run(len_r=13)                      # above the length window
    # positional filter: match deep in both suffixes cannot reach need
    assert not run(pos_r=8, pos_s=8, lo=0, hi=20)
    # self-join triangle
    assert run(self_join=True)
    assert not run(self_join=True, idx_r=7, idx_s=7)
    assert not run(self_join=True, idx_r=9, idx_s=7)


def test_bounds_positional_twin_matches_host():
    rng = np.random.default_rng(0)
    from repro.core import bounds
    lr = rng.integers(1, 30, size=200)
    ls = rng.integers(1, 30, size=200)
    pr = rng.integers(0, 10, size=200)
    ps = rng.integers(0, 10, size=200)
    want = bounds.positional_upper_bound(lr, ls, pr, ps)
    got = np.asarray(bounds.positional_upper_bound_int(
        jnp.asarray(lr), jnp.asarray(ls), jnp.asarray(pr), jnp.asarray(ps)))
    assert np.array_equal(want, got)
