"""Multi-device behaviour on fake CPU devices (subprocess so the main test
process keeps its single real device):

* sharded train step on a (pod, data, model) mesh == single-device step;
* distributed ring join == oracle pair set;
* sharded-indexed join: shard-count invariance (pairs + summed funnel ==
  the single-device indexed driver), forced per-shard overflow escalation,
  hot-slab (uneven token partition) exactness;
* elastic checkpoint restore onto a different mesh shape.
"""

import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess-heavy; deselect with -m "not slow"

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, n_dev: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from repro import configs
from repro.configs.shapes import demo_batch
from repro.models import Model
from repro.train import OptimizerConfig, init_state, make_train_step
from repro.train import step as step_lib
from repro.launch.mesh import make_mesh, named
from repro.distributed.sharding import activation_sharding

cfg = configs.get_reduced("qwen3-8b")
model = Model(cfg)
opt = OptimizerConfig(learning_rate=1e-3, warmup_steps=2, decay_steps=10)
state = init_state(model, opt, jax.random.PRNGKey(0))
batch = demo_batch(cfg, 8, 16)
ref_state, ref_metrics = jax.jit(make_train_step(model, opt))(state, batch)

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
ss = step_lib.state_specs(model, opt, mesh)
bs = step_lib.batch_specs(model, mesh)
with mesh, activation_sharding(mesh):
    jitted = jax.jit(make_train_step(model, opt),
                     in_shardings=named(mesh, (ss, bs)),
                     out_shardings=named(mesh, (ss, None)))
    sh_state, sh_metrics = jitted(state, batch)
np.testing.assert_allclose(float(ref_metrics["loss"]), float(sh_metrics["loss"]),
                           rtol=1e-4)
for a, b in zip(jax.tree.leaves(ref_state["params"]), jax.tree.leaves(sh_state["params"])):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               rtol=3e-3, atol=3e-4)
print("SHARDED == SINGLE OK")
"""))


def test_ring_join_matches_oracle():
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import bitmap as bm, join
from repro.data.collections import uniform_collection, with_duplicates
from repro.launch.mesh import make_mesh

col = with_duplicates(uniform_collection(72, 10, 200, seed=3), n_clusters=6,
                      cluster_size=2, jaccard=0.9, seed=4)
from repro.core.collection import pad_collection
n_dev = 4
col = pad_collection(col, ((col.num_sets + n_dev - 1)//n_dev)*n_dev)
mesh = make_mesh((4,), ("data",))
tokens = jnp.asarray(col.tokens); lengths = jnp.asarray(col.lengths)
words = bm.generate_bitmaps(tokens, lengths, 64, method="xor")
pairs, valid, counters, overflow = join.ring_join_sharded(
    tokens, lengths, words, mesh=mesh, axis="data", sim="jaccard", tau=0.8)
pairs = np.asarray(pairs)[np.asarray(valid)]
got = np.unique(np.sort(pairs, axis=1), axis=0)
oracle = join.naive_join(col, "jaccard", 0.8)
assert len(oracle) > 0
assert np.array_equal(np.sort(got.ravel()), np.sort(oracle.ravel())), (got, oracle)
c = np.asarray(counters)
assert c[:, 2].sum() == 0  # no capacity overflow
assert not np.asarray(overflow).any()
print("RING JOIN OK", len(oracle), "pairs")
"""))


def test_ring_join_rs_matches_oracle():
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import bitmap as bm, join
from repro.core.collection import from_lists
from repro.launch.mesh import make_mesh

rng = np.random.default_rng(7)
sets_r = [rng.choice(60, size=rng.integers(2, 12), replace=False).tolist() for _ in range(32)]
sets_s = [rng.choice(60, size=rng.integers(2, 12), replace=False).tolist() for _ in range(24)]
for k in range(4):
    sets_s[k] = sets_r[3 * k]
L = 12
col_r = from_lists(sets_r, pad_to=L); col_s = from_lists(sets_s, pad_to=L)
mesh = make_mesh((4,), ("data",))
tr, lr = jnp.asarray(col_r.tokens), jnp.asarray(col_r.lengths)
ts, ls = jnp.asarray(col_s.tokens), jnp.asarray(col_s.lengths)
wr = bm.generate_bitmaps(tr, lr, 64, method="xor")
ws = bm.generate_bitmaps(ts, ls, 64, method="xor")
pairs, valid, counters, overflow = join.ring_join_sharded(
    tr, lr, wr, tokens_s=ts, lengths_s=ls, words_s=ws,
    mesh=mesh, axis="data", sim="jaccard", tau=0.6)
got = np.unique(np.asarray(pairs)[np.asarray(valid)], axis=0)
oracle = join.naive_join(col_r, col_s, "jaccard", 0.6)
assert len(oracle) >= 4
assert np.array_equal(got, oracle), (got, oracle)
assert not np.asarray(overflow).any()
print("RING RS JOIN OK", len(oracle), "pairs")
"""))


def test_ring_join_overflow_flagged_per_step():
    """A step whose candidate count exceeds the capacity must trip both the
    per-device overflow counter and the per-step flag (its pairs are
    incomplete — the caller re-runs flagged steps densely)."""
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import bitmap as bm, join
from repro.core.collection import from_lists
from repro.launch.mesh import make_mesh

# 16 identical sets: every pair is a candidate, so capacity 1 overflows.
sets = [[1, 2, 3, 4, 5]] * 16
col = from_lists(sets)
mesh = make_mesh((4,), ("data",))
tok, length = jnp.asarray(col.tokens), jnp.asarray(col.lengths)
words = bm.generate_bitmaps(tok, length, 64, method="xor")
pairs, valid, counters, overflow = join.ring_join_sharded(
    tok, length, words, mesh=mesh, axis="data", sim="jaccard", tau=0.8,
    capacity_per_step=1)
c = np.asarray(counters)
of = np.asarray(overflow)
assert c[:, 0].sum() == 16 * 15 // 2  # all pairs are candidates
assert c[:, 2].sum() > 0              # aggregate counter trips
assert of.any()                       # ...and the per-step flags locate them
assert of.sum() == c[:, 2].sum()      # flags and counter agree
# flagged (device, step) tiles are exactly those with n_cand > cap, so the
# un-flagged steps' output is complete: with cap=1, valid slots <= 1/step.
assert np.asarray(valid).reshape(4, 4, 1).sum(-1).max() <= 1
print("OVERFLOW FLAGGED OK", int(of.sum()), "steps")
"""))


def test_ring_join_driver_exact_across_sims_and_capacities():
    """The ring_join overflow re-run driver (the escalation that
    ring_join_sharded's docstring promises): for every similarity function,
    both an ample and a deliberately tiny per-step capacity must reproduce
    the naive oracle's pair set exactly — tiny capacities via the dense
    re-run of flagged (device, step) tiles."""
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import bitmap as bm, join
from repro.core.collection import from_lists
from repro.launch.mesh import make_mesh

rng = np.random.default_rng(11)
n = 48
sets = [rng.choice(90, size=rng.integers(1, 13), replace=False).tolist()
        for _ in range(n)]
for i in range(0, 12, 3):  # planted duplicates -> non-empty joins + overflow
    sets[i + 1] = sets[i]
col = from_lists(sets, pad_to=14)
mesh = make_mesh((4,), ("data",))
tok, length = jnp.asarray(col.tokens), jnp.asarray(col.lengths)
words = bm.generate_bitmaps(tok, length, 64, method="xor")
saw_overflow = False
for sim, tau in (("jaccard", 0.7), ("cosine", 0.8), ("dice", 0.75), ("overlap", 4.0)):
    oracle = join.naive_join(col, sim, tau)
    assert len(oracle) >= 4, (sim, tau)
    for cap in (None, 1, 4):
        pairs, counters, overflow = join.ring_join(
            tok, length, words, mesh=mesh, axis="data", sim=sim, tau=tau,
            capacity_per_step=cap, return_stats=True)
        assert np.array_equal(pairs, oracle), (sim, tau, cap, len(pairs), len(oracle))
        # verified counters are reconciled with the dense re-runs
        assert np.asarray(counters)[:, 1].sum() == len(pairs), (sim, tau, cap)
        if cap is not None:
            # counter/flag contract: aggregate per-device counters and the
            # per-step flags must agree.
            assert np.asarray(overflow).sum() == np.asarray(counters)[:, 2].sum()
            saw_overflow = saw_overflow or bool(np.asarray(overflow).any())
assert saw_overflow  # the tiny capacities did exercise the re-run path
print("RING DRIVER OK")
"""))


def test_ring_join_driver_rs_overflow():
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import bitmap as bm, join
from repro.core.collection import from_lists
from repro.launch.mesh import make_mesh

rng = np.random.default_rng(13)
sr = [rng.choice(70, size=rng.integers(2, 12), replace=False).tolist() for _ in range(32)]
ss = [rng.choice(70, size=rng.integers(2, 12), replace=False).tolist() for _ in range(24)]
for k in range(6):
    ss[k] = sr[2 * k]
cr = from_lists(sr, pad_to=12); cs = from_lists(ss, pad_to=12)
mesh = make_mesh((4,), ("data",))
tr, lr = jnp.asarray(cr.tokens), jnp.asarray(cr.lengths)
ts, ls = jnp.asarray(cs.tokens), jnp.asarray(cs.lengths)
wr = bm.generate_bitmaps(tr, lr, 64, method="xor")
ws = bm.generate_bitmaps(ts, ls, 64, method="xor")
oracle = join.naive_join(cr, cs, "jaccard", 0.6)
assert len(oracle) >= 6
for cap in (None, 1):
    got = join.ring_join(tr, lr, wr, tokens_s=ts, lengths_s=ls, words_s=ws,
                         mesh=mesh, axis="data", sim="jaccard", tau=0.6,
                         capacity_per_step=cap)
    assert np.array_equal(got, oracle), (cap, len(got), len(oracle))
print("RING DRIVER RS OK")
"""))


def test_ring_join_prepared_pads_and_remaps():
    """ring_join_prepared: prepared collections whose sizes do NOT divide the
    device count are padded with empty sets, bitmap words come from the
    prepared cache (built once across two calls), and pairs come back in
    original (unsorted) indices — exactly the naive oracle's set."""
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import join
from repro.core.engine import prepare
from repro.core.collection import from_lists
from repro.launch.mesh import make_mesh

rng = np.random.default_rng(17)
# 42 and 26 are not multiples of 4 -> the wrapper must pad both sides.
sr = [rng.choice(70, size=rng.integers(2, 12), replace=False).tolist() for _ in range(42)]
ss = [rng.choice(70, size=rng.integers(2, 12), replace=False).tolist() for _ in range(26)]
for k in range(6):
    ss[k] = sr[3 * k]
cr = from_lists(sr, pad_to=12); cs = from_lists(ss, pad_to=12)
mesh = make_mesh((4,), ("data",))
pr, ps = prepare(cr), prepare(cs)
oracle = join.naive_join(cr, cs, "jaccard", 0.6)
assert len(oracle) >= 6
got = join.ring_join_prepared(pr, ps, mesh=mesh, axis="data",
                              sim="jaccard", tau=0.6, b=64, method="xor")
assert np.array_equal(got, oracle), (len(got), len(oracle))
# second call: cached words, no rebuild, same pairs
again = join.ring_join_prepared(pr, ps, mesh=mesh, axis="data",
                                sim="jaccard", tau=0.6, b=64, method="xor")
assert np.array_equal(again, oracle)
assert pr.builds["bitmap"] == 1 and ps.builds["bitmap"] == 1, (pr.builds, ps.builds)
# self-join flavour on an odd-sized collection
oracle_self = join.naive_join(cr, "jaccard", 0.7)
got_self = join.ring_join_prepared(pr, mesh=mesh, axis="data",
                                   sim="jaccard", tau=0.7, b=64, method="xor")
assert np.array_equal(got_self, oracle_self), (len(got_self), len(oracle_self))
print("RING PREPARED OK", len(oracle), len(oracle_self))
"""))


def test_sharded_indexed_shard_count_invariance():
    """The sharded-indexed driver on 1/2/4/8 token slabs must return the
    bit-identical pair set AND summed funnel counters as the single-device
    indexed driver — self-join and R×S — with the per-shard host count
    prepass partitioning the unsharded expansion count exactly, the base
    CSR built once (re-partitioned per shard count) and each partition
    built once."""
    print(_run(r"""
import numpy as np, jax
from repro.core import join
from repro.core.engine import prepare
from repro.core.collection import from_lists
from repro.distributed.sharded_index import sharded_indexed_join_prepared
from repro.index import indexed_join_prepared
from repro.index.candidates import probe_prefix_lengths
from repro.index.postings import shard_expansion_counts
from repro.launch.mesh import make_mesh

rng = np.random.default_rng(23)
base = [rng.choice(90, size=rng.integers(2, 12), replace=False).tolist() for _ in range(16)]
sets = []
for _ in range(64):
    src = base[int(rng.integers(len(base)))]
    sets.append([t for t in src if rng.random() > 0.12] or src[:1])
col = from_lists(sets, pad_to=12)
prep = prepare(col)
oracle = join.naive_join(col, "jaccard", 0.7)
assert len(oracle) > 10
ref_pairs, ref_stats = indexed_join_prepared(prep, sim="jaccard", tau=0.7,
                                             b=32, probe_block=16, return_stats=True)
assert np.array_equal(ref_pairs, oracle)
for n in (1, 2, 4, 8):
    mesh = make_mesh((n,), ("data",))
    got, stats = sharded_indexed_join_prepared(
        prep, mesh=mesh, axis="data", sim="jaccard", tau=0.7, b=32,
        probe_block=16, return_stats=True)
    assert np.array_equal(got, ref_pairs), n
    assert stats.to_dict() == ref_stats.to_dict(), (n, stats.to_dict(), ref_stats.to_dict())
    # the per-shard count prepass partitions the unsharded expansion count
    sharded = prep.sharded_postings("jaccard", 0.7, 1, n)
    ps_np, lp = probe_prefix_lengths(prep, "jaccard", 0.7)
    lo, hi, _, _ = prep.length_window_int("jaccard", 0.7)
    per = shard_expansion_counts(sharded, prep.tokens, ps_np, lo, hi, lp)
    assert per.shape == (n,) and int(per.sum()) == ref_stats.postings_expanded, (n, per)
assert prep.builds["postings"] == 1, prep.builds          # base CSR shared
assert prep.builds["sharded_postings"] == 4, prep.builds  # one partition per n

# R×S flavour on 8 shards
sets_s = [rng.choice(90, size=rng.integers(2, 12), replace=False).tolist() for _ in range(24)]
for k in range(5):
    sets_s[k] = sets[3 * k]
ps = prepare(from_lists(sets_s, pad_to=12))
orc = join.naive_join(col, ps.source, "jaccard", 0.6)
assert len(orc) >= 5
rp, rs = indexed_join_prepared(prep, ps, sim="jaccard", tau=0.6, b=32,
                               probe_block=16, return_stats=True)
gp, gs = sharded_indexed_join_prepared(prep, ps, mesh=make_mesh((8,), ("data",)),
                                       axis="data", sim="jaccard", tau=0.6,
                                       b=32, probe_block=16, return_stats=True)
assert np.array_equal(rp, orc) and np.array_equal(gp, orc)
assert gs.to_dict() == rs.to_dict(), (gs.to_dict(), rs.to_dict())
print("SHARD COUNT INVARIANCE OK", len(oracle), len(orc))
"""))


def test_sharded_indexed_forced_overflow_escalates():
    """Forced per-shard capacities 1-8 on a duplicate-heavy collection:
    overflowing chunks must escalate to the dense path without losing a
    single pair, keep the summed funnel bit-identical to the single-device
    indexed driver at the same capacity, and actually trip the overflow
    counter at the tiny caps."""
    print(_run(r"""
import numpy as np, jax
from repro.core import join
from repro.core.engine import prepare
from repro.core.collection import from_lists
from repro.distributed.sharded_index import sharded_indexed_join_prepared
from repro.index import indexed_join_prepared
from repro.launch.mesh import make_mesh

rng = np.random.default_rng(31)
base = [rng.choice(110, size=rng.integers(2, 13), replace=False).tolist() for _ in range(12)]
sets = []
for _ in range(48):
    src = base[int(rng.integers(len(base)))]
    sets.append([t for t in src if rng.random() > 0.15] or src[:1])
col = from_lists(sets, pad_to=16)
prep = prepare(col)
mesh = make_mesh((8,), ("data",))
saw_overflow = False
for sim, tau in (("jaccard", 0.6), ("cosine", 0.8)):
    oracle = join.naive_join(col, sim, tau)
    assert len(oracle) > 0, (sim, tau)
    for cap in range(1, 9):
        got, stats = sharded_indexed_join_prepared(
            prep, mesh=mesh, axis="data", sim=sim, tau=tau, b=32,
            probe_block=16, capacity=cap, return_stats=True)
        ref, rstats = indexed_join_prepared(
            prep, sim=sim, tau=tau, b=32, probe_block=16, capacity=cap,
            return_stats=True)
        assert np.array_equal(got, oracle), (sim, tau, cap, len(got), len(oracle))
        assert stats.to_dict() == rstats.to_dict(), (sim, tau, cap)
        saw_overflow = saw_overflow or stats.overflow_blocks > 0
assert saw_overflow  # the tiny caps did exercise the escalation
print("SHARDED OVERFLOW OK")
"""))


def test_sharded_indexed_hot_slab_stays_exact():
    """Uneven token-slab partitions: a zipf-hot token universe puts most of
    the postings volume in a handful of tokens, so one slab is hot no
    matter how the balancer cuts.  The allgather-compact reduce must keep
    the result exact and the summed funnel identical to single-device."""
    print(_run(r"""
import numpy as np, jax
from repro.core import join
from repro.core.engine import prepare
from repro.core.collection import from_lists
from repro.distributed.sharded_index import sharded_indexed_join_prepared
from repro.index import indexed_join_prepared
from repro.launch.mesh import make_mesh

rng = np.random.default_rng(41)
sets = []
for _ in range(72):
    sz = int(rng.integers(2, 12))
    toks = np.unique(np.minimum(rng.zipf(1.25, size=3 * sz + 6), 60))[:sz]
    sets.append(toks.tolist())
for i in range(0, 18, 3):  # planted duplicates -> non-empty joins
    sets[i + 1] = sets[i]
col = from_lists(sets, pad_to=12)
prep = prepare(col)
oracle = join.naive_join(col, "jaccard", 0.6)
assert len(oracle) >= 6
mesh = make_mesh((8,), ("data",))
got, stats = sharded_indexed_join_prepared(
    prep, mesh=mesh, axis="data", sim="jaccard", tau=0.6, b=32,
    probe_block=16, return_stats=True)
ref, rstats = indexed_join_prepared(prep, sim="jaccard", tau=0.6, b=32,
                                    probe_block=16, return_stats=True)
assert np.array_equal(got, oracle) and np.array_equal(ref, oracle)
assert stats.to_dict() == rstats.to_dict()
# the partition really is uneven: zipf postings cannot balance 8 ways
sharded = prep.sharded_postings("jaccard", 0.6, 1, 8)
assert sharded.counts.max() >= 2 * max(int(sharded.counts.min()), 1), sharded.counts
print("HOT SLAB OK", sharded.counts.tolist())
"""))


def test_elastic_restore_different_mesh():
    print(_run(r"""
import tempfile, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed import CheckpointManager
from repro.launch.mesh import make_mesh

x = jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)
mesh_a = make_mesh((8,), ("data",))
mesh_b = make_mesh((2, 4), ("data", "model"))
xa = jax.device_put(x, NamedSharding(mesh_a, P("data", None)))
d = tempfile.mkdtemp()
mgr = CheckpointManager(d)
mgr.save(1, {"w": xa})
shapes = {"w": jax.ShapeDtypeStruct(x.shape, x.dtype)}
tgt = {"w": NamedSharding(mesh_b, P("data", "model"))}
restored, at = mgr.restore(shapes, tgt)
assert at == 1
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
assert restored["w"].sharding == tgt["w"]
print("ELASTIC RESTORE OK")
"""))


def test_compressed_pmean_unbiased():
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.launch.mesh import make_mesh
from repro.train.compress import compressed_pmean

mesh = make_mesh((4,), ("pod",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(4, 1024)) * 0.01, jnp.float32)

def local(gs, seed):
    return compressed_pmean({"g": gs[0]}, "pod", jax.random.PRNGKey(seed[0, 0]))["g"][None]

f = shard_map(local, mesh=mesh, in_specs=(P("pod"), P("pod")), out_specs=P("pod"),
              check_rep=False)
true_mean = np.asarray(g).mean(axis=0)
outs = []
for s in range(24):
    seeds = jnp.full((4, 1), s, jnp.int32) * 4 + jnp.arange(4)[:, None].astype(jnp.int32)
    res = np.asarray(f(g, seeds))
    np.testing.assert_allclose(res[0], res[1])  # all devices agree
    outs.append(res[0])
err_single = np.abs(outs[0] - true_mean).max()
err_avg = np.abs(np.mean(outs, axis=0) - true_mean).max()
assert err_avg < err_single  # stochastic rounding averages out (unbiased)
scale = np.abs(np.asarray(g)).max() / 127
assert err_single < 2 * scale
print("COMPRESSED PMEAN OK")
"""))


def test_dryrun_cell_small_mesh():
    """A real lower+compile of a reduced config on a (2,2,2) mesh including
    prefill/decode paths — the fast proxy for the 512-device dry-run."""
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.models import DecodeEngine, Model
from repro.launch.mesh import make_mesh, named
from repro.distributed.sharding import activation_sharding

cfg = configs.get_reduced("zamba2-7b")
model = Model(cfg)
eng = DecodeEngine(model)
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
pspecs = model.param_specs(mesh)
cspecs = eng.cache_specs(mesh, 8)
pin = jax.tree.map(lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
        sharding=NamedSharding(mesh, sp)), model.param_shapes(), pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
cshapes = eng.cache_shapes(8, 64)
cin = jax.tree.map(lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
        sharding=NamedSharding(mesh, sp)), cshapes, cspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
with mesh, activation_sharding(mesh):
    c = jax.jit(eng.decode_step,
                in_shardings=named(mesh, (pspecs, cspecs, {"tokens": P(("pod","data"), None)})),
                out_shardings=named(mesh, (P(("pod","data"), None, None), cspecs)),
                ).lower(pin, cin, {"tokens": tok}).compile()
ma = c.memory_analysis()
assert ma.temp_size_in_bytes >= 0
print("DECODE DRYRUN OK", ma.argument_size_in_bytes)
"""))
