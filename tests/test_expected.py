"""Eq. 4-6 closed forms, Monte-Carlo agreement (paper §3.4), cutoff points
and the Bitmap-Combined crossovers (paper constants)."""

import math

import numpy as np
import pytest

from repro.core import expected
from repro.core.bitmap import choose_method
from repro.core.constants import BITMAP_NEXT, BITMAP_SET, BITMAP_XOR


def test_xor_closed_form_equals_printed_sum():
    """Our parity closed form == the paper's explicit odd-k binomial sum."""
    for b in (32, 64, 128):
        for n in (1, 3, 10, 40):
            closed = float(expected.expected_bound_xor(b, n))
            printed = expected.expected_bound_xor_sum(b, n)
            assert math.isclose(closed, printed, rel_tol=1e-10), (b, n)


@pytest.mark.parametrize("method", [BITMAP_SET, BITMAP_XOR, BITMAP_NEXT])
def test_monte_carlo_matches_equations(method):
    """Paper reports avg error ~0.012%; we allow MC noise at 4k trials.
    Eq. 6 (Next) is itself the paper's *approximation* min(n²/b, n) — its
    intrinsic error at small n is ~1-2%, so it gets a looser band."""
    tol = 0.03 if method == BITMAP_NEXT else 0.01
    for n in (8, 40, 100):
        ana = float(expected.expected_bound(method, 64, n))
        mc = expected.monte_carlo_expected_bound(method, 64, n, trials=4000)
        assert abs(ana - mc) / max(ana, 1e-9) < tol, (method, n, ana, mc)


def test_combined_crossovers_match_paper():
    """Paper Alg. 6: Next below 0.56, Set in (0.56, 0.73), Xor above 0.73 —
    on the normalised-overlap scale (see expected.py docstring)."""
    lo, hi = expected.combined_crossovers_normalized(64)
    assert abs(lo - 0.56) < 0.02, lo
    assert abs(hi - 0.73) < 0.02, hi


def test_cutoff_values_match_paper_fig6():
    """Paper §3.5: b=1024, tau_j=0.9 -> Set cutoff 2129, Xor 4983 (2.3x);
    at tau_j=0.8 the ratio is 1.47x."""
    cs = expected.cutoff_point(BITMAP_SET, 1024, 0.9)
    cx = expected.cutoff_point(BITMAP_XOR, 1024, 0.9)
    assert abs(cs - 2129) <= 2, cs
    assert abs(cx - 4983) / 4983 < 0.03, cx
    assert abs(cx / cs - 2.3) < 0.1
    r8 = expected.cutoff_point(BITMAP_XOR, 1024, 0.8) / expected.cutoff_point(
        BITMAP_SET, 1024, 0.8)
    assert abs(r8 - 1.47) < 0.02, r8


def test_choose_method_regions():
    b = 64
    lo, hi = expected.combined_crossovers(b)
    assert choose_method(lo - 0.02, b) == BITMAP_NEXT
    assert choose_method((lo + hi) / 2, b) == BITMAP_SET
    assert choose_method(hi + 0.02, b) == BITMAP_XOR
    # Paper experiments: tau_j in [0.5, 0.95] should mostly pick Xor
    # (Fig. 10: "Bitmap-Xor was consistently the best option").
    assert choose_method(0.8, b) == BITMAP_XOR
    assert choose_method(0.6, b) == BITMAP_XOR


def test_cutoff_monotonic_in_b():
    for m in (BITMAP_SET, BITMAP_XOR, BITMAP_NEXT):
        cuts = [expected.cutoff_point(m, b, 0.8) for b in (64, 256, 1024)]
        assert cuts == sorted(cuts), (m, cuts)
