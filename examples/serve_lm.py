"""Serving example: prefill + batched greedy decode with the KV/SSM cache.

    PYTHONPATH=src python examples/serve_lm.py [arch]
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import DecodeEngine, Model

arch = sys.argv[1] if len(sys.argv) > 1 else "zamba2-7b"   # hybrid: KV + SSM caches
cfg = configs.get_reduced(arch)
model = Model(cfg)
engine = DecodeEngine(model)
params = model.init(jax.random.PRNGKey(0))

B, PROMPT, GEN = 4, 24, 16
rng = np.random.default_rng(0)
prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, PROMPT)), jnp.int32)

batch = {"tokens": prompt}
if cfg.family == "vlm":
    batch["image_embeds"] = jnp.zeros((B, cfg.num_image_tokens, cfg.d_model), jnp.float32)
if cfg.frame_inputs:
    batch = {"frame_embeds": jnp.asarray(
        rng.normal(size=(B, PROMPT, cfg.d_model)), jnp.float32)}

logits, cache = jax.jit(lambda p, b: engine.prefill(p, b, max_len=PROMPT + GEN))(params, batch)
print(f"{arch}: prefilled {PROMPT} tokens; cache keys: {sorted(cache)}")

step = jax.jit(engine.decode_step)
tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
out = [tok]
for _ in range(GEN - 1):
    if cfg.frame_inputs:
        sb = {"frame_embeds": jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)}
    else:
        sb = {"tokens": tok}
    logits, cache = step(params, cache, sb)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out.append(tok)
gen = jnp.concatenate(out, axis=1)
print(f"greedy-decoded {GEN} tokens per sequence: {np.asarray(gen)[0][:10]}...")
print("serve_step OK")
