"""Quickstart: exact set-similarity joins (self and R×S) with the Bitmap
Filter.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import from_lists, preprocess_rs, JACCARD
from repro.core.join import blocked_bitmap_join, naive_join
from repro.data.collections import uniform_collection, with_duplicates

# 1. Build a collection (or bring your own token sets).
base = uniform_collection(n_sets=3000, avg_size=12, n_tokens=800, seed=0)
col = with_duplicates(base, n_clusters=40, cluster_size=3, jaccard=0.9, seed=1)
print(f"collection: {col.num_sets} sets, max |r| = {col.max_len}")

# 2. Exact join at Jaccard >= 0.8, accelerated by the Bitmap Filter
#    (Bitmap-Combined generation, Eq. 2 pruning, cutoff from Eq. 4-6).
pairs, stats = blocked_bitmap_join(col, JACCARD, 0.8, b=128, return_stats=True)
print(f"similar pairs: {len(pairs)}")
print(f"bitmap filter pruned {stats.filter_ratio:.1%} of length-surviving pairs")
print(f"verification precision: {stats.precision:.1%}")

# 3. It is exact: identical to the naive O(N^2) oracle.
oracle = naive_join(col, JACCARD, 0.8)
assert np.array_equal(pairs, oracle)
print("matches the naive oracle exactly — no false negatives, no false positives")

# 4. Two-collection R×S join (the paper's general problem statement): pass a
#    second collection; pairs come back as (r_index, s_index).  preprocess_rs
#    relabels both sides with one shared token-frequency order.
rng = np.random.default_rng(2)
shard_a = [rng.choice(800, size=rng.integers(4, 16), replace=False).tolist()
           for _ in range(1500)]
shard_b = [rng.choice(800, size=rng.integers(4, 16), replace=False).tolist()
           for _ in range(1000)]
shard_b[:20] = shard_a[:20]  # overlap between the shards
col_r, col_s = preprocess_rs(from_lists(shard_a), from_lists(shard_b))
rs_pairs, rs_stats = blocked_bitmap_join(col_r, col_s, JACCARD, 0.8, b=128,
                                         return_stats=True)
print(f"R×S join: {len(rs_pairs)} cross-collection pairs, "
      f"filter ratio {rs_stats.filter_ratio:.1%}")
assert np.array_equal(rs_pairs, naive_join(col_r, col_s, JACCARD, 0.8))
print("R×S matches the oracle exactly")

# 5. The serving shape: prepare R once, stream probe batches against it.
#    JoinEngine resolves an explicit JoinPlan (driver, bitmap method, block
#    size, compaction mode — inspect it with .describe()) and reuses the
#    corpus-side artifacts (length sort, packed bitmap words, length windows)
#    across every probe — the build counters prove it.
from repro.core import JoinEngine, JoinPlanner

engine = JoinEngine(col_r, JACCARD, 0.8, planner=JoinPlanner(naive_cells=0))
print(engine.plan.describe())
half = col_s.num_sets // 2
from repro.core.collection import Collection
batch_1 = Collection(tokens=col_s.tokens[:half], lengths=col_s.lengths[:half])
batch_2 = Collection(tokens=col_s.tokens[half:], lengths=col_s.lengths[half:])
p1, s1 = engine.probe(batch_1)
p2, s2 = engine.probe(batch_2)
print(f"probe 1: {len(p1)} pairs (filter ratio {s1.filter_ratio:.1%}); "
      f"probe 2: {len(p2)} pairs")
builds = engine.prepared.builds
assert builds["sort"] == 1 and builds["bitmap"] == 1  # built once, reused
merged = np.concatenate([p1, p2 + np.array([0, half])], axis=0)
merged = merged[np.lexsort((merged[:, 1], merged[:, 0]))]
assert np.array_equal(merged, rs_pairs)
print(f"streamed probes match the one-shot R×S join exactly; "
      f"corpus artifacts built once: {builds}")
