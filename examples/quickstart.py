"""Quickstart: exact set-similarity self-join with the Bitmap Filter.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import from_lists, preprocess, JACCARD
from repro.core.join import blocked_bitmap_join, naive_join
from repro.data.collections import uniform_collection, with_duplicates

# 1. Build a collection (or bring your own token sets).
base = uniform_collection(n_sets=3000, avg_size=12, n_tokens=800, seed=0)
col = with_duplicates(base, n_clusters=40, cluster_size=3, jaccard=0.9, seed=1)
print(f"collection: {col.num_sets} sets, max |r| = {col.max_len}")

# 2. Exact join at Jaccard >= 0.8, accelerated by the Bitmap Filter
#    (Bitmap-Combined generation, Eq. 2 pruning, cutoff from Eq. 4-6).
pairs, stats = blocked_bitmap_join(col, JACCARD, 0.8, b=128, return_stats=True)
print(f"similar pairs: {len(pairs)}")
print(f"bitmap filter pruned {stats.filter_ratio:.1%} of length-surviving pairs")
print(f"verification precision: {stats.precision:.1%}")

# 3. It is exact: identical to the naive O(N^2) oracle.
oracle = naive_join(col, JACCARD, 0.8)
assert np.array_equal(pairs, oracle)
print("matches the naive oracle exactly — no false negatives, no false positives")
