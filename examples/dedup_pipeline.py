"""Near-duplicate dedup of an LM corpus — the paper's technique as a
first-class data-pipeline stage.

    PYTHONPATH=src python examples/dedup_pipeline.py
"""

from repro.data.collections import uniform_collection, with_duplicates
from repro.data.dedup import dedup_collection, dedup_documents

# Document-level: shingle -> bitmap join -> union-find -> keep one per cluster.
docs = [
    "the quick brown fox jumps over the lazy dog",
    "the quick brown fox jumps over the lazy cat",
    "a completely different training document about TPUs",
    "the quick brown fox jumps over the lazy dog!",
    "exact set similarity joins with bitwise operations",
] * 200  # simulate a crawl with heavy duplication
kept, res = dedup_documents(docs, tau=0.5)
print(f"{len(docs)} docs -> {len(kept)} after exact near-dup removal "
      f"(pruned {res.stats.filter_ratio:.1%} of candidate pairs via bitmaps)")

# Token-set-level (pre-tokenised corpora).
base = uniform_collection(n_sets=5000, avg_size=15, n_tokens=2000, seed=3)
col = with_duplicates(base, n_clusters=100, cluster_size=4, jaccard=0.92, seed=4)
res = dedup_collection(col, tau=0.85, b=128)
print(f"{col.num_sets} sets -> keep {len(res.keep)}, drop {len(res.drop)} "
      f"({len(res.pairs)} similar pairs found)")
