"""End-to-end driver: train a (reduced) assigned architecture for a few
hundred steps with the production substrate — dedup'd synthetic pipeline,
pjit step, async checkpoints, fault-tolerant runner.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen3-8b]

Any of the 10 assigned archs work via --arch; full configs need the real
mesh (see src/repro/launch/dryrun.py for the 256/512-chip lowering).
"""

import sys

from repro.launch.train import train_main

args = sys.argv[1:] or ["--arch", "smollm-135m"]
train_main(args + [
    "--reduced", "--steps", "300", "--batch", "8", "--seq", "64",
    "--ckpt-every", "100", "--ckpt-dir", "/tmp/repro_example_ckpt",
    "--log-every", "25", "--lr", "3e-3",
])
